//! Graceful degradation for execution paths: run the fast (fused /
//! winograd / tiled) path, catch panics and errors, and re-run on the
//! next-simpler *verified* path instead of dying. The fallback paths are
//! the same naive oracles every fast path is bitwise-validated against in
//! tests, so a degraded answer is still a correct answer.
//!
//! This extends the autotuner's probe-and-fallback discipline from
//! tuning-time to request-time: the fast path is an optimization, never a
//! correctness dependency.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::conv::Tensor4;
use crate::obs::{self, js, Level};
use crate::testkit::faults;
use crate::util::error::{Context, Error, ErrorKind, Result};
use crate::util::threadpool::panic_message;

use super::backend::{Executable, FaultStats};

/// Convert a caught panic payload into a typed [`ErrorKind::WorkerPanicked`]
/// error carrying the panic message.
pub fn panic_to_error(payload: Box<dyn std::any::Any + Send>) -> Error {
    Error::typed(
        ErrorKind::WorkerPanicked,
        format!("worker panicked: {}", panic_message(payload.as_ref())),
    )
}

/// Run `f`, converting an unwind into a typed error. Does NOT consult the
/// fault harness — use for fallback/retry attempts that must be immune to
/// injected faults.
pub fn catch_only<T>(f: impl FnOnce() -> Result<T>) -> Result<T> {
    match catch_unwind(AssertUnwindSafe(f)) {
        Ok(r) => r,
        Err(p) => Err(panic_to_error(p)),
    }
}

/// Run `f` as a *primary* attempt: the fault harness's `exec:error` rules
/// fire first, then an unwind is converted to a typed error.
pub fn run_guarded<T>(f: impl FnOnce() -> Result<T>) -> Result<T> {
    if faults::armed() {
        faults::exec_error_point()?;
    }
    catch_only(f)
}

/// Emit the observable record of a caught panic (counted by
/// `trace summarize` into its `panicked` counter).
pub fn note_panic(key: &str, path: &str, e: &Error) {
    if e.kind() == ErrorKind::WorkerPanicked && obs::enabled() {
        obs::event(
            obs::kind::WORKER_PANIC,
            &[("key", js(key)), ("path", js(path)), ("cause", js(&e.to_string()))],
        );
    }
}

/// Emit the observable record of a degradation (counted by
/// `trace summarize` into its `degraded` counter) and a log line.
pub fn note_degrade(key: &str, from: &str, to: &str, e: &Error) {
    obs::log(
        Level::Warn,
        &format!("'{key}': '{from}' path failed ({e}); degrading to '{to}'"),
    );
    if obs::enabled() {
        obs::event(
            obs::kind::DEGRADE,
            &[
                ("key", js(key)),
                ("from", js(from)),
                ("to", js(to)),
                ("cause", js(&e.to_string())),
            ],
        );
    }
}

/// CLI-side helper: run `primary`; on a panic or injected fault, record
/// it and re-run `fallback`. Returns the output plus whether it degraded
/// (callers skip measured-traffic gates for degraded runs — the naive
/// fallback paths are uncounted).
pub fn run_recovering<T>(
    key: &str,
    from: &str,
    to: &str,
    primary: impl FnOnce() -> T,
    fallback: impl FnOnce() -> T,
) -> (T, bool) {
    match run_guarded(|| Ok(primary())) {
        Ok(v) => (v, false),
        Err(e) => {
            note_panic(key, from, &e);
            note_degrade(key, from, to, &e);
            (fallback(), true)
        }
    }
}

/// A fault-tolerant shell around a primary [`Executable`]: panics are
/// caught and counted, and when a verified fallback executable is
/// attached, a failed primary attempt re-runs there (recording the
/// downgrade) instead of surfacing the error.
pub struct FallbackExec {
    key: String,
    /// Label of the primary path (e.g. `"fused"`, `"winograd"`, `"tiled"`).
    from: &'static str,
    /// Label of the fallback path (e.g. `"layered"`, `"naive"`).
    to: &'static str,
    primary: Box<dyn Executable>,
    fallback: Option<Box<dyn Executable>>,
    /// Clears the primary's partial traffic counts after a failed attempt,
    /// so a degraded run doesn't leave half-charged words behind.
    reset: Option<Box<dyn Fn() + Send + Sync>>,
    panicked: AtomicU64,
    degraded: AtomicU64,
}

impl FallbackExec {
    /// Full shell: primary + fallback + counter-reset hook.
    pub fn new(
        key: impl Into<String>,
        from: &'static str,
        to: &'static str,
        primary: Box<dyn Executable>,
        fallback: Box<dyn Executable>,
        reset: Option<Box<dyn Fn() + Send + Sync>>,
    ) -> FallbackExec {
        FallbackExec {
            key: key.into(),
            from,
            to,
            primary,
            fallback: Some(fallback),
            reset,
            panicked: AtomicU64::new(0),
            degraded: AtomicU64::new(0),
        }
    }

    /// Catch-only shell for paths that *are* the simplest verified path
    /// (naive, im2col): panics become typed errors, nothing to degrade to.
    pub fn guard(
        key: impl Into<String>,
        from: &'static str,
        primary: Box<dyn Executable>,
    ) -> FallbackExec {
        FallbackExec {
            key: key.into(),
            from,
            to: "none",
            primary,
            fallback: None,
            reset: None,
            panicked: AtomicU64::new(0),
            degraded: AtomicU64::new(0),
        }
    }

    /// Handle a failed primary attempt: count/trace it, then either
    /// degrade to the fallback or surface the typed error.
    fn recover(
        &self,
        e: Error,
        rerun: impl FnOnce(&dyn Executable) -> Result<Tensor4>,
    ) -> Result<Tensor4> {
        if e.kind() == ErrorKind::WorkerPanicked {
            self.panicked.fetch_add(1, Ordering::Relaxed);
        }
        note_panic(&self.key, self.from, &e);
        if let Some(reset) = &self.reset {
            reset();
        }
        let Some(fb) = &self.fallback else {
            return Err(e.context(format!("'{}' path of '{}' failed", self.from, self.key)));
        };
        self.degraded.fetch_add(1, Ordering::Relaxed);
        note_degrade(&self.key, self.from, self.to, &e);
        // the fallback must not re-trip injected faults (it is the
        // recovery), so it runs catch-only
        catch_only(|| rerun(fb.as_ref()))
            .with_context(|| format!("'{}' fallback of '{}' failed too", self.to, self.key))
    }
}

impl Executable for FallbackExec {
    fn execute(&self, inputs: &[&Tensor4]) -> Result<Tensor4> {
        match run_guarded(|| self.primary.execute(inputs)) {
            Ok(out) => Ok(out),
            Err(e) => self.recover(e, |fb| fb.execute(inputs)),
        }
    }

    fn execute_arc(&self, inputs: &[Arc<Tensor4>]) -> Result<Tensor4> {
        match run_guarded(|| self.primary.execute_arc(inputs)) {
            Ok(out) => Ok(out),
            Err(e) => self.recover(e, |fb| fb.execute_arc(inputs)),
        }
    }

    fn traffic(&self) -> Option<crate::kernels::Traffic> {
        self.primary.traffic()
    }

    fn stage_traffic(&self) -> Option<Vec<crate::kernels::Traffic>> {
        self.primary.stage_traffic()
    }

    fn halo_words(&self) -> Option<Vec<u64>> {
        self.primary.halo_words()
    }

    fn fault_stats(&self) -> Option<FaultStats> {
        Some(FaultStats {
            panicked: self.panicked.load(Ordering::Relaxed),
            degraded: self.degraded.load(Ordering::Relaxed),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Fixed(f32);
    impl Executable for Fixed {
        fn execute(&self, _inputs: &[&Tensor4]) -> Result<Tensor4> {
            let mut t = Tensor4::zeros([1, 1, 1, 1]);
            t.data[0] = self.0;
            Ok(t)
        }
    }

    struct Exploding;
    impl Executable for Exploding {
        fn execute(&self, _inputs: &[&Tensor4]) -> Result<Tensor4> {
            panic!("kaboom");
        }
    }

    #[test]
    fn panicking_primary_degrades_to_fallback() {
        let fb = FallbackExec::new(
            "test/exploding",
            "fast",
            "naive",
            Box::new(Exploding),
            Box::new(Fixed(42.0)),
            None,
        );
        let out = fb.execute(&[]).unwrap();
        assert_eq!(out.data[0], 42.0);
        let s = fb.fault_stats().unwrap();
        assert_eq!(s, FaultStats { panicked: 1, degraded: 1 });
        // a second failure keeps counting
        let _ = fb.execute(&[]).unwrap();
        assert_eq!(fb.fault_stats().unwrap().panicked, 2);
    }

    #[test]
    fn guarded_primary_without_fallback_surfaces_typed_error() {
        let fb = FallbackExec::guard("test/exploding", "naive", Box::new(Exploding));
        let e = fb.execute(&[]).unwrap_err();
        assert_eq!(e.kind(), ErrorKind::WorkerPanicked);
        assert!(e.to_string().contains("kaboom"), "got: {e}");
        let s = fb.fault_stats().unwrap();
        assert_eq!(s, FaultStats { panicked: 1, degraded: 0 });
    }

    #[test]
    fn healthy_primary_passes_through_untouched() {
        let fb = FallbackExec::new(
            "test/fixed",
            "fast",
            "naive",
            Box::new(Fixed(7.0)),
            Box::new(Fixed(0.0)),
            None,
        );
        assert_eq!(fb.execute(&[]).unwrap().data[0], 7.0);
        assert_eq!(fb.fault_stats().unwrap(), FaultStats::default());
    }

    #[test]
    fn reset_hook_runs_on_failure() {
        use std::sync::atomic::AtomicUsize;
        let hits = Arc::new(AtomicUsize::new(0));
        let h = Arc::clone(&hits);
        let fb = FallbackExec::new(
            "test/exploding",
            "fast",
            "naive",
            Box::new(Exploding),
            Box::new(Fixed(1.0)),
            Some(Box::new(move || {
                h.fetch_add(1, Ordering::SeqCst);
            })),
        );
        let _ = fb.execute(&[]).unwrap();
        assert_eq!(hits.load(Ordering::SeqCst), 1);
    }
}
