//! HLO-text statistics: the L2 performance lens.
//!
//! The lowered module is the ground truth for what XLA will execute; this
//! lightweight parser extracts the op histogram, dot/convolution FLOP
//! estimates and peak intermediate footprint so EXPERIMENTS.md §Perf L2 can
//! assert "no redundant recomputation, fused where XLA can fuse" from the
//! artifact itself rather than guesswork.

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::error::{Context, Result};

/// Aggregate statistics of one HLO module.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct HloStats {
    /// instruction-count histogram by opcode
    pub ops: BTreeMap<String, usize>,
    /// total instructions
    pub total: usize,
    /// MAC count from `dot` ops (product of contracted/batch/free dims)
    pub dot_macs: u64,
    /// total f32 elements across all instruction output shapes
    pub output_elements: u64,
    /// number of fusion computations
    pub fusions: usize,
    /// number of while loops (interpret-mode pallas grids lower to these)
    pub while_loops: usize,
}

/// Parse the shape `f32[4,8,14,14]{...}` → element count.
fn shape_elements(shape: &str) -> Option<u64> {
    let open = shape.find('[')?;
    let close = shape[open..].find(']')? + open;
    let dims = &shape[open + 1..close];
    if dims.trim().is_empty() {
        return Some(1); // scalar
    }
    let mut n: u64 = 1;
    for d in dims.split(',') {
        n = n.checked_mul(d.trim().parse::<u64>().ok()?)?;
    }
    Some(n)
}

/// Extract the opcode from an instruction line `x = f32[..] op-name(...)`
/// (names may or may not carry a leading `%`; ROOT lines included).
fn parse_instruction(line: &str) -> Option<(String, u64)> {
    let line = line.trim();
    let first = line.split_whitespace().next()?;
    let name = if first == "ROOT" {
        line.split_whitespace().nth(1)?
    } else {
        first
    };
    // instruction names are identifiers like `add.7` or `%fusion.3`
    if !name
        .chars()
        .all(|c| c.is_ascii_alphanumeric() || matches!(c, '%' | '.' | '_' | '-'))
    {
        return None;
    }
    let eq = line.find(" = ")?;
    let rest = &line[eq + 3..];
    // rest looks like: "f32[4,8]{1,0} opcode(args...)" or "(f32[..]) tuple(...)"
    let paren = rest.find('(')?;
    // opcode is the last word before the paren
    let head = &rest[..paren];
    let opcode = head.split_whitespace().last()?.to_string();
    // skip tuple-shape heads like "(f32[2,2])" (opcode would contain '[')
    if opcode.contains('[') || opcode.contains('{') {
        // e.g. "(f32[2,2]) tuple" — retry on the text after ')'
        let close = rest.find(") ")?;
        let tail = &rest[close + 2..];
        let p2 = tail.find('(')?;
        let op2 = tail[..p2].split_whitespace().last()?.to_string();
        let elems = shape_elements(rest).unwrap_or(0);
        return Some((op2, elems));
    }
    let elems = shape_elements(head).unwrap_or(0);
    Some((opcode, elems))
}

/// Parse the dims list of the first shape on a line: `f32[4,8]{..}` → [4,8].
fn shape_dims(shape: &str) -> Option<Vec<u64>> {
    let open = shape.find('[')?;
    let close = shape[open..].find(']')? + open;
    let dims = &shape[open + 1..close];
    if dims.trim().is_empty() {
        return Some(vec![]);
    }
    dims.split(',').map(|d| d.trim().parse::<u64>().ok()).collect()
}

/// MAC count of a `dot` line: |out| × (product of contracted lhs dims).
/// Operand shapes are not repeated on HLO-text dot lines, so the caller
/// passes a symbol table of instruction-name → dims.
fn dot_macs_of_line(
    line: &str,
    symbols: &BTreeMap<String, Vec<u64>>,
) -> u64 {
    let out_elems = shape_elements(line).unwrap_or(0);
    // lhs operand: first argument inside dot(...) — scan to the first
    // comma at bracket depth 0 (shape annotations contain commas), then
    // take the last whitespace token (strips an optional shape prefix)
    let Some(p) = line.find("dot(") else { return 0 };
    let args = &line[p + 4..];
    let mut depth = 0i32;
    let mut end = args.len();
    for (i, c) in args.char_indices() {
        match c {
            '[' | '{' | '(' => depth += 1,
            ']' | '}' => depth -= 1,
            ')' if depth > 0 => depth -= 1,
            ',' | ')' if depth == 0 => {
                end = i;
                break;
            }
            _ => {}
        }
    }
    let lhs_name = args[..end]
        .split_whitespace()
        .last()
        .unwrap_or("")
        .trim_start_matches('%');
    let Some(lhs_dims) = symbols.get(lhs_name) else { return 0 };
    // contracted dims: lhs_contracting_dims={k,...}
    let contracted: u64 = line
        .find("lhs_contracting_dims={")
        .and_then(|q| {
            let rest = &line[q + 22..];
            let end = rest.find('}')?;
            Some(
                rest[..end]
                    .split(',')
                    .filter_map(|d| d.trim().parse::<usize>().ok())
                    .filter_map(|k| lhs_dims.get(k).copied())
                    .product(),
            )
        })
        .unwrap_or(1);
    out_elems * contracted
}

/// Compute stats from HLO text.
pub fn analyze_text(text: &str) -> HloStats {
    let mut st = HloStats::default();
    // first pass: symbol table of instruction output shapes
    let mut symbols: BTreeMap<String, Vec<u64>> = BTreeMap::new();
    for line in text.lines() {
        let t = line.trim();
        let name = if let Some(rest) = t.strip_prefix("ROOT ") {
            rest.split_whitespace().next()
        } else {
            t.split_whitespace().next()
        };
        if let (Some(name), Some(eq)) = (name, t.find(" = ")) {
            if let Some(dims) = shape_dims(&t[eq + 3..]) {
                symbols.insert(name.trim_start_matches('%').to_string(), dims);
            }
        }
    }
    for line in text.lines() {
        if let Some((op, elems)) = parse_instruction(line) {
            *st.ops.entry(op.clone()).or_insert(0) += 1;
            st.total += 1;
            st.output_elements = st.output_elements.saturating_add(elems);
            match op.as_str() {
                "dot" => st.dot_macs += dot_macs_of_line(line, &symbols),
                "fusion" => st.fusions += 1,
                "while" => st.while_loops += 1,
                _ => {}
            }
        }
    }
    st
}

/// Load + analyze an artifact file.
pub fn analyze_file(path: impl AsRef<Path>) -> Result<HloStats> {
    let text = std::fs::read_to_string(path.as_ref())
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    Ok(analyze_text(&text))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
HloModule test
ENTRY main {
  %p0 = f32[4,8]{1,0} parameter(0)
  %p1 = f32[8,6]{1,0} parameter(1)
  %d = f32[4,6]{1,0} dot(f32[4,8]{1,0} %p0, f32[8,6]{1,0} %p1), lhs_contracting_dims={1}
  %c = f32[] constant(2)
  %b = f32[4,6]{1,0} broadcast(f32[] %c), dimensions={}
  ROOT %a = f32[4,6]{1,0} add(f32[4,6]{1,0} %d, f32[4,6]{1,0} %b)
}
"#;

    #[test]
    fn histogram_and_total() {
        let st = analyze_text(SAMPLE);
        assert_eq!(st.ops.get("parameter"), Some(&2));
        assert_eq!(st.ops.get("dot"), Some(&1));
        assert_eq!(st.ops.get("add"), Some(&1));
        assert_eq!(st.total, 6);
    }

    #[test]
    fn dot_macs_estimated() {
        let st = analyze_text(SAMPLE);
        // (4,8)x(8,6): 4·8·6 = 192 = sqrt(32·48·24)
        assert_eq!(st.dot_macs, 192);
    }

    #[test]
    fn shape_elements_parsing() {
        assert_eq!(shape_elements("f32[4,8,14,14]{3,2,1,0}"), Some(4 * 8 * 14 * 14));
        assert_eq!(shape_elements("f32[]"), Some(1));
        assert_eq!(shape_elements("nope"), None);
    }

    #[test]
    fn real_artifacts_have_dots_matching_updates() {
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if !dir.join("manifest.json").exists() {
            return;
        }
        let manifest =
            crate::runtime::Manifest::load(dir.join("manifest.json")).unwrap();
        let spec = manifest.find("unit1x1/blocked").unwrap();
        let st = analyze_file(dir.join(&spec.path)).unwrap();
        assert!(st.ops.contains_key("dot") || st.while_loops > 0,
                "blocked conv must lower to dots or a grid loop: {:?}", st.ops);
        assert!(st.total > 10);
    }
}
