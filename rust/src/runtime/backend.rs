//! The pluggable execution backend: how artifacts are prepared and run,
//! decoupled from the [`super::Runtime`]'s manifest/caching/validation
//! logic.
//!
//! Two implementations exist:
//!
//! * [`super::native`] — the default: executes single-layer conv specs with
//!   the crate's own kernels ([`crate::conv::naive`] and an im2col+GEMM
//!   path), needs no artifact files, no Python, no external crates;
//! * `super::pjrt` (cargo feature `pjrt`) — loads AOT-lowered HLO text and
//!   executes it on the XLA PJRT CPU client, exactly as the original
//!   three-layer stack did.
//!
//! The split mirrors the paper's own separation between the analytic tiling
//! model and the execution substrate it drives: planners and servers talk
//! to [`ExecBackend`], never to a concrete runtime.

use std::path::Path;
use std::sync::Arc;

use crate::conv::Tensor4;
use crate::util::error::Result;

use super::manifest::{ArtifactSpec, NetworkSpec};

/// Fault counters an executable accumulated over its lifetime: panics
/// caught (and converted to typed errors) and runs that degraded to a
/// fallback execution path. See [`super::fallback::FallbackExec`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Caught worker/kernel panics (one per failed attempt).
    pub panicked: u64,
    /// Runs re-executed on a simpler verified path after a failure.
    pub degraded: u64,
}

impl FaultStats {
    /// Component-wise sum.
    pub fn add(&mut self, other: FaultStats) {
        self.panicked += other.panicked;
        self.degraded += other.degraded;
    }
}

/// A prepared (compiled / lowered / specialized) artifact, ready to run.
pub trait Executable {
    /// Execute on host tensors and return the single output tensor.
    ///
    /// Callers must validate `inputs` against the artifact's manifest spec
    /// first; [`super::LoadedArtifact::run`] does so and is the intended
    /// entry point.
    fn execute(&self, inputs: &[&Tensor4]) -> Result<Tensor4>;

    /// Execute on shared host tensors. The default delegates to
    /// [`Executable::execute`]; backends whose hot path hands operands to
    /// worker threads (the native `"tiled"`/`"network"` kinds) override it
    /// to reuse the caller's `Arc`s instead of cloning the tensors.
    fn execute_arc(&self, inputs: &[Arc<Tensor4>]) -> Result<Tensor4> {
        let refs: Vec<&Tensor4> = inputs.iter().map(|t| t.as_ref()).collect();
        self.execute(&refs)
    }

    /// Cumulative word traffic this executable has charged, when the
    /// backend instruments it (the native `"tiled"` and `"network"` kinds
    /// do); `None` for uninstrumented executables.
    fn traffic(&self) -> Option<crate::kernels::Traffic> {
        None
    }

    /// Per-stage traffic snapshots for network pipelines (stage order);
    /// `None` for single-layer executables.
    fn stage_traffic(&self) -> Option<Vec<crate::kernels::Traffic>> {
        None
    }

    /// Per-stage words the fused executor served from its sliding-window
    /// halo cache (stage order): group heads avoided main-memory re-reads,
    /// interior fused stages avoided upstream recompute. `None` for
    /// single-layer executables.
    fn halo_words(&self) -> Option<Vec<u64>> {
        None
    }

    /// Panic/degrade counters, when the backend wraps this executable in
    /// a fault-tolerant shell (the native backend's
    /// [`super::fallback::FallbackExec`] does); `None` for unwrapped
    /// executables.
    fn fault_stats(&self) -> Option<FaultStats> {
        None
    }
}

/// An execution substrate that prepares artifacts for execution.
pub trait ExecBackend {
    /// Human-readable platform name (e.g. `"native-cpu"`, PJRT's `"Host"`).
    fn platform(&self) -> String;

    /// Prepare one artifact.
    ///
    /// `path` is the artifact's on-disk location when the runtime has a
    /// backing directory; spec-driven backends (native) ignore it, while
    /// file-based backends (PJRT) fail without it.
    fn load(
        &mut self,
        spec: &ArtifactSpec,
        path: Option<&Path>,
    ) -> Result<Box<dyn Executable>>;

    /// Does this backend execute whole-network pipelines natively through
    /// [`ExecBackend::load_network`]? Default `false`: when a manifest
    /// carries a `networks` section (AOT manifests from
    /// `python/compile/aot.py` now emit one) the runtime only routes
    /// `"network"` artifacts through `load_network` on backends that opt
    /// in — file-based backends (PJRT) keep loading the lowered HLO
    /// module instead.
    fn supports_networks(&self) -> bool {
        false
    }

    /// Prepare a whole-network pipeline artifact. `net` is the resolved
    /// [`NetworkSpec`] the `"network"` spec's name refers to (strides of
    /// interior stages are not recoverable from the spec's dims alone).
    /// The default refuses: backends opt into network execution.
    fn load_network(
        &mut self,
        net: &NetworkSpec,
        spec: &ArtifactSpec,
    ) -> Result<Box<dyn Executable>> {
        let _ = net;
        Err(crate::err!(
            "backend '{}' cannot execute network pipeline '{}'",
            self.platform(),
            spec.key()
        ))
    }
}
