//! The pluggable execution backend: how artifacts are prepared and run,
//! decoupled from the [`super::Runtime`]'s manifest/caching/validation
//! logic.
//!
//! Two implementations exist:
//!
//! * [`super::native`] — the default: executes single-layer conv specs with
//!   the crate's own kernels ([`crate::conv::naive`] and an im2col+GEMM
//!   path), needs no artifact files, no Python, no external crates;
//! * `super::pjrt` (cargo feature `pjrt`) — loads AOT-lowered HLO text and
//!   executes it on the XLA PJRT CPU client, exactly as the original
//!   three-layer stack did.
//!
//! The split mirrors the paper's own separation between the analytic tiling
//! model and the execution substrate it drives: planners and servers talk
//! to [`ExecBackend`], never to a concrete runtime.

use std::path::Path;

use crate::conv::Tensor4;
use crate::util::error::Result;

use super::manifest::ArtifactSpec;

/// A prepared (compiled / lowered / specialized) artifact, ready to run.
pub trait Executable {
    /// Execute on host tensors and return the single output tensor.
    ///
    /// Callers must validate `inputs` against the artifact's manifest spec
    /// first; [`super::LoadedArtifact::run`] does so and is the intended
    /// entry point.
    fn execute(&self, inputs: &[&Tensor4]) -> Result<Tensor4>;

    /// Cumulative word traffic this executable has charged, when the
    /// backend instruments it (the native `"tiled"` kind does); `None`
    /// for uninstrumented executables.
    fn traffic(&self) -> Option<crate::kernels::Traffic> {
        None
    }
}

/// An execution substrate that prepares artifacts for execution.
pub trait ExecBackend {
    /// Human-readable platform name (e.g. `"native-cpu"`, PJRT's `"Host"`).
    fn platform(&self) -> String;

    /// Prepare one artifact.
    ///
    /// `path` is the artifact's on-disk location when the runtime has a
    /// backing directory; spec-driven backends (native) ignore it, while
    /// file-based backends (PJRT) fail without it.
    fn load(
        &mut self,
        spec: &ArtifactSpec,
        path: Option<&Path>,
    ) -> Result<Box<dyn Executable>>;
}
