//! Artifact manifest: the JSON index written by python/compile/aot.py
//! describing every AOT-compiled HLO module's entry shapes — plus
//! [`Manifest::builtin`], a synthetic manifest of small single-layer conv
//! specs *and* a whole-network pipeline ([`NetworkSpec`]) that the native
//! backend executes with no files on disk at all.

use std::path::Path;

use crate::conv::{ConvPass, ConvShape, Precision};
use crate::err;
use crate::util::error::{Context, Result};
use crate::util::json::Json;

/// Batch size of [`Manifest::builtin`] as used by the zero-setup paths
/// (`Runtime::builtin`, `ConvServer::start_builtin`) — one constant so the
/// validator and the executor can never disagree.
pub const BUILTIN_BATCH: u64 = 4;

// One stage of a network pipeline — a conv layer plus the word-precision
// model its tile plan is solved under. The type lives in `conv::shapes`
// (next to ConvShape/Precision) so the kernels layer never depends on the
// manifest; the chain-validation logic below is what this module owns.
pub use crate::conv::NetworkStage;

/// An ordered chain of conv layers served as one unit: the first-class
/// network pipeline the fusion planner (`kernels/fuse.rs`) and the fused
/// executor operate on. Stage `k+1` consumes stage `k`'s activation
/// directly, so the chain must satisfy the paper's input convention at
/// every boundary: `cI(k+1) = cO(k)` and
/// `σw(k+1)·wO(k+1) + wF(k+1) = wO(k)` (likewise in h).
#[derive(Debug, Clone, PartialEq)]
pub struct NetworkSpec {
    pub name: String,
    pub stages: Vec<NetworkStage>,
}

impl NetworkSpec {
    /// Build and validate a network chain. Errors on an empty chain, a
    /// degenerate stage (zero-extent dim), a stride exceeding its filter
    /// (the split-filter loops assume `σ ≤ f`), or a boundary where stage
    /// `k+1`'s paper-convention input is not exactly stage `k`'s output.
    pub fn new(name: &str, stages: Vec<NetworkStage>) -> Result<NetworkSpec> {
        if stages.is_empty() {
            return Err(err!("network '{name}': empty stage chain"));
        }
        for (k, st) in stages.iter().enumerate() {
            let s = &st.shape;
            // checked product: parsed dims near the strict-integer cap
            // must surface as an error, not a multiply overflow
            let macs = [s.n, s.c_i, s.c_o, s.w_o, s.h_o, s.w_f, s.h_f]
                .iter()
                .try_fold(1u64, |acc, &d| acc.checked_mul(d));
            match macs {
                None => {
                    return Err(err!(
                        "network '{name}' stage {k}: MAC count overflows \
                         u64 ({s})"
                    ))
                }
                Some(0) => {
                    return Err(err!(
                        "network '{name}' stage {k}: degenerate shape ({s})"
                    ))
                }
                Some(_) => {}
            }
            if s.s_w == 0 || s.s_h == 0 {
                return Err(err!(
                    "network '{name}' stage {k}: zero stride ({s})"
                ));
            }
            if s.s_w > s.w_f || s.s_h > s.h_f {
                return Err(err!(
                    "network '{name}' stage {k}: stride exceeds filter ({s})"
                ));
            }
        }
        for k in 1..stages.len() {
            let prev = &stages[k - 1].shape;
            let cur = &stages[k].shape;
            if cur.n != prev.n {
                return Err(err!(
                    "network '{name}': stage {k} batch {} != stage {} batch {}",
                    cur.n,
                    k - 1,
                    prev.n
                ));
            }
            if cur.c_i != prev.c_o
                || cur.in_w() != prev.w_o
                || cur.in_h() != prev.h_o
            {
                return Err(err!(
                    "network '{name}': stage {k} input ({} ch, {}x{}) does \
                     not chain onto stage {} output ({} ch, {}x{})",
                    cur.c_i,
                    cur.in_w(),
                    cur.in_h(),
                    k - 1,
                    prev.c_o,
                    prev.w_o,
                    prev.h_o
                ));
            }
        }
        Ok(NetworkSpec { name: name.to_string(), stages })
    }

    /// A uniform-precision chain from bare shapes.
    pub fn uniform(name: &str, shapes: &[ConvShape]) -> Result<NetworkSpec> {
        NetworkSpec::new(
            name,
            shapes
                .iter()
                .map(|s| NetworkStage { shape: *s, precision: Precision::uniform() })
                .collect(),
        )
    }

    /// The builtin three-stage tiny ResNet-style chain: a unit-stride 3×3
    /// head, a unit-stride 3×3 body, and a strided 2×2 tail, sized so the
    /// whole pipeline's fused working set fits comfortably in the default
    /// tile-memory budget (one fused group end to end).
    pub fn tiny_resnet(batch: u64) -> NetworkSpec {
        assert!(batch >= 1);
        NetworkSpec::uniform(
            "tiny_resnet",
            &[
                ConvShape::new(batch, 3, 8, 13, 13, 3, 3, 1, 1),
                ConvShape::new(batch, 8, 16, 10, 10, 3, 3, 1, 1),
                ConvShape::new(batch, 16, 32, 4, 4, 2, 2, 2, 2),
            ],
        )
        .expect("builtin tiny_resnet chain is valid")
    }

    /// The builtin six-stage mixed pipeline: a shallow fusable head
    /// (3×3 → 3×3 → 2×2 at growing channel counts), a 48→64-channel 5×5
    /// stage whose filter panel alone exceeds the default tile-memory
    /// budget — forcing the fusion planner to materialize around it — and
    /// a strided tail. CI exercises the mixed fused/materialized network
    /// path by default through this entry.
    pub fn deep_mixnet(batch: u64) -> NetworkSpec {
        assert!(batch >= 1);
        NetworkSpec::uniform(
            "deep_mixnet",
            &[
                ConvShape::new(batch, 3, 8, 20, 20, 3, 3, 1, 1),
                ConvShape::new(batch, 8, 16, 17, 17, 3, 3, 1, 1),
                ConvShape::new(batch, 16, 48, 15, 15, 2, 2, 1, 1),
                ConvShape::new(batch, 48, 64, 10, 10, 5, 5, 1, 1),
                ConvShape::new(batch, 64, 16, 7, 7, 3, 3, 1, 1),
                ConvShape::new(batch, 16, 32, 2, 2, 3, 3, 2, 2),
            ],
        )
        .expect("builtin deep_mixnet chain is valid")
    }

    /// Batch size N shared by every stage.
    pub fn batch(&self) -> u64 {
        self.stages[0].shape.n
    }

    /// Image input dims `(N, cI, WI, HI)` of the first stage.
    pub fn input_dims(&self) -> [usize; 4] {
        let s = &self.stages[0].shape;
        [s.n as usize, s.c_i as usize, s.in_w() as usize, s.in_h() as usize]
    }

    /// Output dims `(N, cO, wO, hO)` of the last stage.
    pub fn output_dims(&self) -> [usize; 4] {
        let s = &self.stages[self.stages.len() - 1].shape;
        [s.n as usize, s.c_o as usize, s.w_o as usize, s.h_o as usize]
    }

    /// Total MAC updates across the chain.
    pub fn updates(&self) -> u64 {
        self.stages.iter().map(|st| st.shape.updates()).sum()
    }
}

/// One artifact entry.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactSpec {
    pub name: String,
    /// "blocked" | "im2col" | "tiled" | "dfilter" | "dinput" | "network"
    /// | "training"
    pub kind: String,
    /// file name relative to the artifact directory
    pub path: String,
    /// input tensor shapes, in call order
    pub inputs: Vec<Vec<usize>>,
    /// output tensor shape (always rank 4 in this crate)
    pub output: Vec<usize>,
    /// total MAC updates G for throughput reporting
    pub updates: u64,
}

impl ArtifactSpec {
    /// Stable lookup key: `<name>/<kind>`.
    pub fn key(&self) -> String {
        format!("{}/{}", self.name, self.kind)
    }

    /// Synthesize the spec of a single-layer conv artifact directly from a
    /// paper-convention [`ConvShape`] (inputs: image then filter). The
    /// `path` is a placeholder — spec-driven backends never read it.
    pub fn for_layer(name: &str, kind: &str, s: &ConvShape) -> ArtifactSpec {
        ArtifactSpec {
            name: name.to_string(),
            kind: kind.to_string(),
            path: format!("{name}_{kind}.hlo.txt"),
            inputs: vec![
                vec![
                    s.n as usize,
                    s.c_i as usize,
                    s.in_w() as usize,
                    s.in_h() as usize,
                ],
                vec![
                    s.c_i as usize,
                    s.c_o as usize,
                    s.w_f as usize,
                    s.h_f as usize,
                ],
            ],
            output: vec![
                s.n as usize,
                s.c_o as usize,
                s.w_o as usize,
                s.h_o as usize,
            ],
            updates: s.updates(),
        }
    }

    /// Synthesize the spec of a gradient-pass artifact (kind `"dfilter"`
    /// or `"dinput"`) for the backward convolutions of layer `s`: inputs
    /// are the pass's `(a, b)` operands ([`ConvPass::operand_dims`] —
    /// (image, dOut) for dFilter, (dOut, filter) for dInput), the output
    /// is the gradient tensor. `updates` is the layer's G (identical for
    /// all three passes of a training step).
    pub fn for_pass(name: &str, pass: ConvPass, s: &ConvShape) -> ArtifactSpec {
        let (a, b) = pass.operand_dims(s);
        ArtifactSpec {
            name: name.to_string(),
            kind: pass.name().to_string(),
            path: format!("{name}_{}.hlo.txt", pass.name()),
            inputs: vec![a.to_vec(), b.to_vec()],
            output: pass.out_dims(s).to_vec(),
            updates: s.updates(),
        }
    }

    /// Synthesize the spec of a whole-network artifact from a validated
    /// [`NetworkSpec`]: inputs are the image followed by one filter per
    /// stage, the output is the last stage's activation. The strides of
    /// interior stages are not recoverable from these dims alone, so
    /// backends resolve the chain through [`Manifest::network`] rather
    /// than inverting the spec.
    pub fn for_network(net: &NetworkSpec) -> ArtifactSpec {
        let mut inputs = vec![{
            let d = net.input_dims();
            vec![d[0], d[1], d[2], d[3]]
        }];
        for st in &net.stages {
            inputs.push(st.shape.filter_dims().to_vec());
        }
        let o = net.output_dims();
        ArtifactSpec {
            name: net.name.clone(),
            kind: "network".to_string(),
            path: format!("{}_network.hlo.txt", net.name),
            inputs,
            output: vec![o[0], o[1], o[2], o[3]],
            updates: net.updates(),
        }
    }

    /// Synthesize the spec of a training-network artifact (kind
    /// `"training"`) from a validated [`NetworkSpec`]: the fused backward
    /// sweep through the whole chain. Inputs are the loss gradient at the
    /// tail followed by one (fixed) filter per stage; the output is the
    /// image gradient `dIn_0` (the head's input dims). As with the
    /// `"network"` kind, interior strides are not recoverable from these
    /// dims, so backends resolve the chain through [`Manifest::network`].
    pub fn for_training(net: &NetworkSpec) -> ArtifactSpec {
        let o = net.output_dims();
        let mut inputs = vec![vec![o[0], o[1], o[2], o[3]]];
        for st in &net.stages {
            inputs.push(st.shape.filter_dims().to_vec());
        }
        let d = net.input_dims();
        ArtifactSpec {
            name: net.name.clone(),
            kind: "training".to_string(),
            path: format!("{}_training.hlo.txt", net.name),
            inputs,
            output: vec![d[0], d[1], d[2], d[3]],
            updates: net.updates(),
        }
    }

    /// Recover the [`ConvShape`] a single-layer (image, filter) spec
    /// encodes, under the paper's input convention `WI = σw·wO + wF`, and
    /// validate that the spec is a consistent conv layer. This is the one
    /// authoritative inversion — the native backend and the integration
    /// tests all derive shapes through it.
    pub fn layer_shape(&self) -> Result<ConvShape> {
        if self.inputs.len() != 2 {
            return Err(err!(
                "'{}': expected (image, filter) inputs, got {}",
                self.key(),
                self.inputs.len()
            ));
        }
        let (i, f, o) = (&self.inputs[0], &self.inputs[1], &self.output);
        if i.len() != 4 || f.len() != 4 || o.len() != 4 {
            return Err(err!("'{}': inputs and output must be rank 4", self.key()));
        }
        if o[2] == 0 || o[3] == 0 || i[2] < f[2] || i[3] < f[3] {
            return Err(err!("'{}': inconsistent spatial dims", self.key()));
        }
        let s_w = (i[2] - f[2]) / o[2];
        let s_h = (i[3] - f[3]) / o[3];
        let s = ConvShape::new(
            o[0] as u64,
            f[0] as u64,
            f[1] as u64,
            o[2] as u64,
            o[3] as u64,
            f[2] as u64,
            f[3] as u64,
            s_w as u64,
            s_h as u64,
        );
        let want_input = vec![o[0], f[0], s.in_w() as usize, s.in_h() as usize];
        if s_w == 0 || s_h == 0 || *i != want_input || o[1] != f[1] {
            return Err(err!(
                "'{}': not a paper-convention conv layer (inputs {:?} / {:?}, \
                 output {:?})",
                self.key(),
                i,
                f,
                o
            ));
        }
        Ok(s)
    }

    /// Recover the *forward* [`ConvShape`] a gradient-pass spec encodes —
    /// the per-pass counterpart of [`ArtifactSpec::layer_shape`] (to which
    /// the forward pass delegates). Validation is by round-trip: the
    /// reconstructed shape must reproduce every operand and output dim of
    /// the spec under the pass's own dim maps, so a spec that is not a
    /// consistent paper-convention gradient problem is rejected at load.
    pub fn pass_shape(&self, pass: ConvPass) -> Result<ConvShape> {
        if pass == ConvPass::Forward {
            return self.layer_shape();
        }
        if self.inputs.len() != 2 {
            return Err(err!(
                "'{}': expected two {} operands, got {} inputs",
                self.key(),
                pass.name(),
                self.inputs.len()
            ));
        }
        let (a, b, o) = (&self.inputs[0], &self.inputs[1], &self.output);
        if a.len() != 4 || b.len() != 4 || o.len() != 4 {
            return Err(err!("'{}': inputs and output must be rank 4", self.key()));
        }
        let bad = || {
            err!(
                "'{}': not a paper-convention {} problem (inputs {:?} / {:?}, \
                 output {:?})",
                self.key(),
                pass.name(),
                a,
                b,
                o
            )
        };
        let s = match pass {
            // a = image (N, cI, WI, HI), b = dOut (N, cO, wO, hO),
            // o = dF (cI, cO, wF, hF)
            ConvPass::DFilter => {
                let (wo, ho, wf, hf) = (b[2], b[3], o[2], o[3]);
                if wo == 0 || ho == 0 || a[2] < wf || a[3] < hf {
                    return Err(bad());
                }
                ConvShape::new(
                    a[0] as u64,
                    a[1] as u64,
                    b[1] as u64,
                    wo as u64,
                    ho as u64,
                    wf as u64,
                    hf as u64,
                    ((a[2] - wf) / wo) as u64,
                    ((a[3] - hf) / ho) as u64,
                )
            }
            // a = dOut (N, cO, wO, hO), b = filter (cI, cO, wF, hF),
            // o = dIn (N, cI, WI, HI)
            ConvPass::DInput => {
                let (wo, ho, wf, hf) = (a[2], a[3], b[2], b[3]);
                if wo == 0 || ho == 0 || o[2] < wf || o[3] < hf {
                    return Err(bad());
                }
                ConvShape::new(
                    a[0] as u64,
                    b[0] as u64,
                    a[1] as u64,
                    wo as u64,
                    ho as u64,
                    wf as u64,
                    hf as u64,
                    ((o[2] - wf) / wo) as u64,
                    ((o[3] - hf) / ho) as u64,
                )
            }
            ConvPass::Forward => unreachable!("handled above"),
        };
        let (wa, wb) = pass.operand_dims(&s);
        if s.s_w == 0
            || s.s_h == 0
            || *a != wa.to_vec()
            || *b != wb.to_vec()
            || *o != pass.out_dims(&s).to_vec()
        {
            return Err(bad());
        }
        Ok(s)
    }
}

/// The whole manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub batch: usize,
    pub artifacts: Vec<ArtifactSpec>,
    /// Network pipelines the `"network"` artifact kinds resolve to; empty
    /// for manifests that only carry single-layer artifacts.
    pub networks: Vec<NetworkSpec>,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Manifest::parse(&text)
    }

    /// The built-in synthetic manifest: small single-layer conv specs
    /// (unit-stride 3×3 and 1×1, plus a strided 5×5) sized so the native
    /// backend answers in well under a millisecond per batch, each exposed
    /// through the kernel kinds the native backend implements (the 3×3 and
    /// strided 5×5 also as `"tiled"`, routing through the `kernels/`
    /// engine, and both also as the training kinds
    /// `"dfilter"`/`"dinput"`, routing the backward convolutions through
    /// the same pass-generic engine), plus two `"network"` pipelines: the
    /// fully-fusable [`NetworkSpec::tiny_resnet`] and the six-stage
    /// [`NetworkSpec::deep_mixnet`], whose plan mixes fused and
    /// materialized groups at the default budget. Each pipeline is also
    /// exposed as a `"training"` artifact: the fused backward sweep that
    /// turns a tail loss gradient into the head image gradient. This is
    /// what [`super::Runtime::builtin`] and the no-artifact serving path
    /// use.
    pub fn builtin(batch: u64) -> Manifest {
        assert!(batch >= 1);
        let unit3x3 = ConvShape::new(batch, 8, 16, 12, 12, 3, 3, 1, 1);
        let unit1x1 = ConvShape::new(batch, 16, 32, 14, 14, 1, 1, 1, 1);
        let unit5x5 = ConvShape::new(batch, 3, 12, 6, 6, 5, 5, 2, 2);
        let tiny = NetworkSpec::tiny_resnet(batch);
        let deep = NetworkSpec::deep_mixnet(batch);
        Manifest {
            batch: batch as usize,
            artifacts: vec![
                ArtifactSpec::for_layer("unit3x3", "blocked", &unit3x3),
                ArtifactSpec::for_layer("unit3x3", "im2col", &unit3x3),
                ArtifactSpec::for_layer("unit3x3", "tiled", &unit3x3),
                ArtifactSpec::for_pass("unit3x3", ConvPass::DFilter, &unit3x3),
                ArtifactSpec::for_pass("unit3x3", ConvPass::DInput, &unit3x3),
                ArtifactSpec::for_layer("unit1x1", "blocked", &unit1x1),
                ArtifactSpec::for_layer("unit5x5", "blocked", &unit5x5),
                ArtifactSpec::for_layer("unit5x5", "tiled", &unit5x5),
                ArtifactSpec::for_pass("unit5x5", ConvPass::DFilter, &unit5x5),
                ArtifactSpec::for_pass("unit5x5", ConvPass::DInput, &unit5x5),
                ArtifactSpec::for_network(&tiny),
                ArtifactSpec::for_network(&deep),
                ArtifactSpec::for_training(&tiny),
                ArtifactSpec::for_training(&deep),
            ],
            networks: vec![tiny, deep],
        }
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Json::parse(text).map_err(|e| err!("manifest: {e}"))?;
        let batch = v
            .get("batch")
            .as_u64()
            .ok_or_else(|| err!("manifest: missing 'batch'"))? as usize;
        let mut artifacts = Vec::new();
        for a in v
            .get("artifacts")
            .as_arr()
            .ok_or_else(|| err!("manifest: missing 'artifacts'"))?
        {
            let shape_list = |key: &str| -> Result<Vec<Vec<usize>>> {
                a.get(key)
                    .as_arr()
                    .ok_or_else(|| err!("manifest: missing '{key}'"))?
                    .iter()
                    .map(|s| {
                        s.as_arr()
                            .ok_or_else(|| err!("bad shape in '{key}'"))
                            .map(|dims| {
                                dims.iter()
                                    .map(|d| d.as_u64().unwrap_or(0) as usize)
                                    .collect()
                            })
                    })
                    .collect()
            };
            artifacts.push(ArtifactSpec {
                name: a
                    .get("name")
                    .as_str()
                    .ok_or_else(|| err!("artifact missing 'name'"))?
                    .to_string(),
                kind: a
                    .get("kind")
                    .as_str()
                    .ok_or_else(|| err!("artifact missing 'kind'"))?
                    .to_string(),
                path: a
                    .get("path")
                    .as_str()
                    .ok_or_else(|| err!("artifact missing 'path'"))?
                    .to_string(),
                inputs: shape_list("inputs")?,
                output: a
                    .get("output")
                    .as_arr()
                    .ok_or_else(|| err!("artifact missing 'output'"))?
                    .iter()
                    .map(|d| d.as_u64().unwrap_or(0) as usize)
                    .collect(),
                updates: a.get("updates").as_u64().unwrap_or(0),
            });
        }
        let mut networks = Vec::new();
        for nv in v.get("networks").as_arr().unwrap_or(&[]) {
            let name = nv
                .get("name")
                .as_str()
                .ok_or_else(|| err!("network missing 'name'"))?
                .to_string();
            let mut stages = Vec::new();
            for sv in nv
                .get("stages")
                .as_arr()
                .ok_or_else(|| err!("network '{name}' missing 'stages'"))?
            {
                let dims = sv
                    .get("shape")
                    .as_arr()
                    .ok_or_else(|| err!("network '{name}': stage missing 'shape'"))?;
                if dims.len() != 9 {
                    return Err(err!(
                        "network '{name}': stage shape wants 9 dims \
                         [N,cI,cO,wO,hO,wF,hF,sw,sh], got {}",
                        dims.len()
                    ));
                }
                // strict: a truncated or defaulted dim would silently load
                // a semantically different network
                let d: Vec<u64> = dims
                    .iter()
                    .map(|x| {
                        x.as_u64_strict().ok_or_else(|| {
                            err!(
                                "network '{name}': shape dim '{x}' is not \
                                 an integer"
                            )
                        })
                    })
                    .collect::<Result<_>>()?;
                let shape = ConvShape::new(
                    d[0], d[1], d[2], d[3], d[4], d[5], d[6], d[7], d[8],
                );
                let precision = match sv.get("precision").as_arr() {
                    None => Precision::uniform(),
                    Some(p) if p.len() == 3 => {
                        // equally strict: a defaulted precision would solve
                        // every tile plan under the wrong word model
                        let word = |j: &Json| match j.as_f64() {
                            Some(v) if v.is_finite() && v > 0.0 => Ok(v),
                            _ => Err(err!(
                                "network '{name}': precision entry '{j}' is \
                                 not a positive number"
                            )),
                        };
                        Precision::new(word(&p[0])?, word(&p[1])?, word(&p[2])?)
                    }
                    Some(_) => {
                        return Err(err!(
                            "network '{name}': 'precision' wants [pI, pF, pO]"
                        ))
                    }
                };
                stages.push(NetworkStage { shape, precision });
            }
            networks.push(NetworkSpec::new(&name, stages)?);
        }
        Ok(Manifest { batch, artifacts, networks })
    }

    /// Find the network pipeline a `"network"` artifact's name refers to.
    pub fn network(&self, name: &str) -> Option<&NetworkSpec> {
        self.networks.iter().find(|n| n.name == name)
    }

    /// Find by `<name>/<kind>` key or bare name (if unique).
    pub fn find(&self, key: &str) -> Option<&ArtifactSpec> {
        if let Some(a) = self.artifacts.iter().find(|a| a.key() == key) {
            return Some(a);
        }
        let by_name: Vec<&ArtifactSpec> =
            self.artifacts.iter().filter(|a| a.name == key).collect();
        if by_name.len() == 1 {
            Some(by_name[0])
        } else {
            None
        }
    }

    pub fn keys(&self) -> Vec<String> {
        self.artifacts.iter().map(|a| a.key()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "batch": 4,
      "artifacts": [
        {"name": "unit3x3", "kind": "blocked", "path": "a.hlo.txt",
         "inputs": [[4,8,14,14],[8,16,3,3]], "output": [4,16,6,6],
         "updates": 663552},
        {"name": "unit3x3", "kind": "im2col", "path": "b.hlo.txt",
         "inputs": [[4,8,14,14],[8,16,3,3]], "output": [4,16,6,6],
         "updates": 663552}
      ]
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.batch, 4);
        assert_eq!(m.artifacts.len(), 2);
        assert_eq!(m.artifacts[0].inputs[0], vec![4, 8, 14, 14]);
        assert_eq!(m.artifacts[0].output, vec![4, 16, 6, 6]);
        assert_eq!(m.artifacts[0].updates, 663552);
    }

    #[test]
    fn find_by_key_and_ambiguous_name() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.find("unit3x3/blocked").is_some());
        assert!(m.find("unit3x3/im2col").is_some());
        // bare name is ambiguous (two kinds) -> None
        assert!(m.find("unit3x3").is_none());
        assert!(m.find("missing").is_none());
    }

    #[test]
    fn parse_rejects_missing_fields() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"batch": 1}"#).is_err());
        assert!(Manifest::parse(
            r#"{"batch": 1, "artifacts": [{"kind": "x"}]}"#
        )
        .is_err());
    }

    #[test]
    fn builtin_manifest_is_well_formed() {
        let m = Manifest::builtin(4);
        assert_eq!(m.batch, 4);
        assert!(m.find("unit3x3/blocked").is_some());
        assert!(m.find("unit3x3/im2col").is_some());
        assert!(m.find("unit3x3/tiled").is_some());
        assert!(m.find("unit5x5/tiled").is_some());
        assert!(m.find("unit1x1/blocked").is_some());
        assert!(m.find("unit3x3/dfilter").is_some());
        assert!(m.find("unit3x3/dinput").is_some());
        assert!(m.find("unit5x5/dfilter").is_some());
        assert!(m.find("unit5x5/dinput").is_some());
        assert!(m.find("tiny_resnet/network").is_some());
        assert!(m.find("tiny_resnet/training").is_some());
        assert!(m.find("deep_mixnet/training").is_some());
        for a in &m.artifacts {
            assert!(a.inputs.len() >= 2, "{}", a.key());
            assert_eq!(a.output.len(), 4);
            assert_eq!(a.inputs[0][0], 4, "batch dim");
            assert!(a.updates > 0);
        }
        // keys are unique
        let mut keys = m.keys();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), m.artifacts.len());
    }

    #[test]
    fn gradient_specs_roundtrip_through_pass_shape() {
        let s = ConvShape::new(4, 3, 12, 6, 5, 5, 4, 2, 1);
        for pass in [ConvPass::DFilter, ConvPass::DInput] {
            let spec = ArtifactSpec::for_pass("g", pass, &s);
            assert_eq!(spec.kind, pass.name());
            assert_eq!(spec.updates, s.updates());
            assert_eq!(spec.pass_shape(pass).expect("roundtrip"), s);
            // a gradient spec is not a single-layer (image, filter) spec
            assert!(spec.layer_shape().is_err(), "{}", spec.key());
            // corrupting any operand dim breaks the round-trip validation
            let mut bad = spec.clone();
            bad.inputs[1][1] += 1;
            assert!(bad.pass_shape(pass).is_err());
            let mut bad = spec.clone();
            bad.output[3] += 1;
            assert!(bad.pass_shape(pass).is_err());
            let mut bad = spec.clone();
            bad.inputs.pop();
            assert!(bad.pass_shape(pass).is_err());
        }
        // the Forward case is the existing layer inversion
        let fwd = ArtifactSpec::for_layer("f", "tiled", &s);
        assert_eq!(fwd.pass_shape(ConvPass::Forward).expect("layer"), s);
    }

    #[test]
    fn builtin_network_chains_and_matches_artifact() {
        let m = Manifest::builtin(4);
        let net = m.network("tiny_resnet").expect("builtin network");
        assert_eq!(net.stages.len(), 3);
        assert_eq!(net.batch(), 4);
        for w in net.stages.windows(2) {
            assert_eq!(w[1].shape.c_i, w[0].shape.c_o);
            assert_eq!(w[1].shape.in_w(), w[0].shape.w_o);
            assert_eq!(w[1].shape.in_h(), w[0].shape.h_o);
            assert!(w[1].shape.paper_assumptions_hold());
        }
        let spec = m.find("tiny_resnet/network").unwrap();
        assert_eq!(spec.inputs.len(), net.stages.len() + 1);
        assert_eq!(spec.inputs[0], net.input_dims().to_vec());
        assert_eq!(spec.output, net.output_dims().to_vec());
        assert_eq!(spec.updates, net.updates());
        // the network artifact is not a single-layer spec
        assert!(spec.layer_shape().is_err());
    }

    #[test]
    fn builtin_deep_network_chains_and_matches_artifact() {
        let m = Manifest::builtin(4);
        let net = m.network("deep_mixnet").expect("builtin deep network");
        assert!(net.stages.len() >= 6, "deep pipeline wants 6+ stages");
        assert_eq!(net.batch(), 4);
        for w in net.stages.windows(2) {
            assert_eq!(w[1].shape.c_i, w[0].shape.c_o);
            assert_eq!(w[1].shape.in_w(), w[0].shape.w_o);
            assert_eq!(w[1].shape.in_h(), w[0].shape.h_o);
            assert!(w[1].shape.paper_assumptions_hold());
        }
        let spec = m.find("deep_mixnet/network").expect("deep artifact");
        assert_eq!(spec.inputs.len(), net.stages.len() + 1);
        assert_eq!(spec.inputs[0], net.input_dims().to_vec());
        assert_eq!(spec.output, net.output_dims().to_vec());
        assert_eq!(spec.updates, net.updates());
    }

    #[test]
    fn training_artifacts_mirror_their_network() {
        let m = Manifest::builtin(4);
        for name in ["tiny_resnet", "deep_mixnet"] {
            let net = m.network(name).expect("builtin network");
            let spec = m
                .find(&format!("{name}/training"))
                .expect("training artifact");
            assert_eq!(spec.kind, "training");
            // operands: tail loss gradient, then one filter per stage
            assert_eq!(spec.inputs.len(), net.stages.len() + 1);
            assert_eq!(spec.inputs[0], net.output_dims().to_vec());
            for (k, st) in net.stages.iter().enumerate() {
                assert_eq!(spec.inputs[k + 1], st.shape.filter_dims().to_vec());
            }
            // the product is the image gradient at the head
            assert_eq!(spec.output, net.input_dims().to_vec());
            assert_eq!(spec.updates, net.updates());
            assert!(spec.layer_shape().is_err());
        }
    }

    #[test]
    fn network_spec_rejects_broken_chains() {
        let a = ConvShape::new(2, 3, 8, 13, 13, 3, 3, 1, 1);
        let good = ConvShape::new(2, 8, 16, 10, 10, 3, 3, 1, 1);
        assert!(NetworkSpec::uniform("ok", &[a, good]).is_ok());
        assert!(NetworkSpec::uniform("empty", &[]).is_err());
        // channel mismatch
        let bad_c = ConvShape::new(2, 9, 16, 10, 10, 3, 3, 1, 1);
        assert!(NetworkSpec::uniform("c", &[a, bad_c]).is_err());
        // spatial mismatch (input 15 != previous output 13)
        let bad_w = ConvShape::new(2, 8, 16, 12, 12, 3, 3, 1, 1);
        assert!(NetworkSpec::uniform("w", &[a, bad_w]).is_err());
        // batch mismatch: channels/spatial chain but N differs
        let bad_n = ConvShape::new(3, 8, 16, 10, 10, 3, 3, 1, 1);
        assert!(NetworkSpec::uniform("n", &[a, bad_n]).is_err());
        // degenerate stage
        let degenerate = ConvShape::new(0, 3, 8, 13, 13, 3, 3, 1, 1);
        assert!(NetworkSpec::uniform("d", &[degenerate]).is_err());
        // stride > filter breaks the split-filter assumption
        let wide_stride = ConvShape::new(2, 3, 8, 4, 4, 2, 2, 3, 3);
        assert!(NetworkSpec::uniform("s", &[wide_stride]).is_err());
        // zero stride is not a convolution this stack executes
        let zero_stride = ConvShape::new(2, 3, 8, 4, 4, 2, 2, 0, 1);
        assert!(NetworkSpec::uniform("z", &[zero_stride]).is_err());
    }

    #[test]
    fn parse_networks_section() {
        let text = r#"{
          "batch": 2,
          "artifacts": [],
          "networks": [
            {"name": "two", "stages": [
              {"shape": [2,3,8,13,13,3,3,1,1]},
              {"shape": [2,8,16,10,10,3,3,1,1], "precision": [1, 1, 2]}
            ]}
          ]
        }"#;
        let m = Manifest::parse(text).unwrap();
        let net = m.network("two").expect("parsed network");
        assert_eq!(net.stages.len(), 2);
        assert_eq!(net.stages[0].precision, Precision::uniform());
        assert_eq!(net.stages[1].precision, Precision::new(1.0, 1.0, 2.0));
        // an inconsistent chain fails to parse
        let bad = r#"{"batch": 2, "artifacts": [], "networks": [
          {"name": "x", "stages": [
            {"shape": [2,3,8,13,13,3,3,1,1]},
            {"shape": [2,9,16,10,10,3,3,1,1]}
          ]}]}"#;
        assert!(Manifest::parse(bad).is_err());
        // a fractional dim must error, not silently truncate the stride
        let frac = r#"{"batch": 2, "artifacts": [], "networks": [
          {"name": "f", "stages": [
            {"shape": [2,3,8,13,13,3,3,1.9,1]}
          ]}]}"#;
        assert!(Manifest::parse(frac).is_err());
        // manifests without the key parse to no networks
        assert!(Manifest::parse(SAMPLE).unwrap().networks.is_empty());
    }

    #[test]
    fn real_manifest_if_present() {
        // integration check against the actual build output when available
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.json");
        if p.exists() {
            let m = Manifest::load(&p).unwrap();
            assert!(!m.artifacts.is_empty());
            for a in &m.artifacts {
                assert_eq!(a.output.len(), 4);
                assert!(!a.inputs.is_empty());
            }
        }
    }
}
