//! Artifact manifest: the JSON index written by python/compile/aot.py
//! describing every AOT-compiled HLO module's entry shapes.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// One artifact entry.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactSpec {
    pub name: String,
    /// "blocked" | "im2col" | "network"
    pub kind: String,
    /// file name relative to the artifact directory
    pub path: String,
    /// input tensor shapes, in call order
    pub inputs: Vec<Vec<usize>>,
    /// output tensor shape (always rank 4 in this crate)
    pub output: Vec<usize>,
    /// total MAC updates G for throughput reporting
    pub updates: u64,
}

impl ArtifactSpec {
    /// Stable lookup key: `<name>/<kind>`.
    pub fn key(&self) -> String {
        format!("{}/{}", self.name, self.kind)
    }
}

/// The whole manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub batch: usize,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Manifest::parse(&text)
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let batch = v
            .get("batch")
            .as_u64()
            .ok_or_else(|| anyhow!("manifest: missing 'batch'"))? as usize;
        let mut artifacts = Vec::new();
        for a in v
            .get("artifacts")
            .as_arr()
            .ok_or_else(|| anyhow!("manifest: missing 'artifacts'"))?
        {
            let shape_list = |key: &str| -> Result<Vec<Vec<usize>>> {
                a.get(key)
                    .as_arr()
                    .ok_or_else(|| anyhow!("manifest: missing '{key}'"))?
                    .iter()
                    .map(|s| {
                        s.as_arr()
                            .ok_or_else(|| anyhow!("bad shape in '{key}'"))
                            .map(|dims| {
                                dims.iter()
                                    .map(|d| d.as_u64().unwrap_or(0) as usize)
                                    .collect()
                            })
                    })
                    .collect()
            };
            artifacts.push(ArtifactSpec {
                name: a
                    .get("name")
                    .as_str()
                    .ok_or_else(|| anyhow!("artifact missing 'name'"))?
                    .to_string(),
                kind: a
                    .get("kind")
                    .as_str()
                    .ok_or_else(|| anyhow!("artifact missing 'kind'"))?
                    .to_string(),
                path: a
                    .get("path")
                    .as_str()
                    .ok_or_else(|| anyhow!("artifact missing 'path'"))?
                    .to_string(),
                inputs: shape_list("inputs")?,
                output: a
                    .get("output")
                    .as_arr()
                    .ok_or_else(|| anyhow!("artifact missing 'output'"))?
                    .iter()
                    .map(|d| d.as_u64().unwrap_or(0) as usize)
                    .collect(),
                updates: a.get("updates").as_u64().unwrap_or(0),
            });
        }
        Ok(Manifest { batch, artifacts })
    }

    /// Find by `<name>/<kind>` key or bare name (if unique).
    pub fn find(&self, key: &str) -> Option<&ArtifactSpec> {
        if let Some(a) = self.artifacts.iter().find(|a| a.key() == key) {
            return Some(a);
        }
        let by_name: Vec<&ArtifactSpec> =
            self.artifacts.iter().filter(|a| a.name == key).collect();
        if by_name.len() == 1 {
            Some(by_name[0])
        } else {
            None
        }
    }

    pub fn keys(&self) -> Vec<String> {
        self.artifacts.iter().map(|a| a.key()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "batch": 4,
      "artifacts": [
        {"name": "unit3x3", "kind": "blocked", "path": "a.hlo.txt",
         "inputs": [[4,8,14,14],[8,16,3,3]], "output": [4,16,6,6],
         "updates": 663552},
        {"name": "unit3x3", "kind": "im2col", "path": "b.hlo.txt",
         "inputs": [[4,8,14,14],[8,16,3,3]], "output": [4,16,6,6],
         "updates": 663552}
      ]
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.batch, 4);
        assert_eq!(m.artifacts.len(), 2);
        assert_eq!(m.artifacts[0].inputs[0], vec![4, 8, 14, 14]);
        assert_eq!(m.artifacts[0].output, vec![4, 16, 6, 6]);
        assert_eq!(m.artifacts[0].updates, 663552);
    }

    #[test]
    fn find_by_key_and_ambiguous_name() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.find("unit3x3/blocked").is_some());
        assert!(m.find("unit3x3/im2col").is_some());
        // bare name is ambiguous (two kinds) -> None
        assert!(m.find("unit3x3").is_none());
        assert!(m.find("missing").is_none());
    }

    #[test]
    fn parse_rejects_missing_fields() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"batch": 1}"#).is_err());
        assert!(Manifest::parse(
            r#"{"batch": 1, "artifacts": [{"kind": "x"}]}"#
        )
        .is_err());
    }

    #[test]
    fn real_manifest_if_present() {
        // integration check against the actual build output when available
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.json");
        if p.exists() {
            let m = Manifest::load(&p).unwrap();
            assert!(!m.artifacts.is_empty());
            for a in &m.artifacts {
                assert_eq!(a.output.len(), 4);
                assert!(!a.inputs.is_empty());
            }
        }
    }
}
