//! Artifact manifest: the JSON index written by python/compile/aot.py
//! describing every AOT-compiled HLO module's entry shapes — plus
//! [`Manifest::builtin`], a synthetic manifest of small single-layer conv
//! specs that the native backend executes with no files on disk at all.

use std::path::Path;

use crate::conv::ConvShape;
use crate::err;
use crate::util::error::{Context, Result};
use crate::util::json::Json;

/// Batch size of [`Manifest::builtin`] as used by the zero-setup paths
/// (`Runtime::builtin`, `ConvServer::start_builtin`) — one constant so the
/// validator and the executor can never disagree.
pub const BUILTIN_BATCH: u64 = 4;

/// One artifact entry.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactSpec {
    pub name: String,
    /// "blocked" | "im2col" | "tiled" | "network"
    pub kind: String,
    /// file name relative to the artifact directory
    pub path: String,
    /// input tensor shapes, in call order
    pub inputs: Vec<Vec<usize>>,
    /// output tensor shape (always rank 4 in this crate)
    pub output: Vec<usize>,
    /// total MAC updates G for throughput reporting
    pub updates: u64,
}

impl ArtifactSpec {
    /// Stable lookup key: `<name>/<kind>`.
    pub fn key(&self) -> String {
        format!("{}/{}", self.name, self.kind)
    }

    /// Synthesize the spec of a single-layer conv artifact directly from a
    /// paper-convention [`ConvShape`] (inputs: image then filter). The
    /// `path` is a placeholder — spec-driven backends never read it.
    pub fn for_layer(name: &str, kind: &str, s: &ConvShape) -> ArtifactSpec {
        ArtifactSpec {
            name: name.to_string(),
            kind: kind.to_string(),
            path: format!("{name}_{kind}.hlo.txt"),
            inputs: vec![
                vec![
                    s.n as usize,
                    s.c_i as usize,
                    s.in_w() as usize,
                    s.in_h() as usize,
                ],
                vec![
                    s.c_i as usize,
                    s.c_o as usize,
                    s.w_f as usize,
                    s.h_f as usize,
                ],
            ],
            output: vec![
                s.n as usize,
                s.c_o as usize,
                s.w_o as usize,
                s.h_o as usize,
            ],
            updates: s.updates(),
        }
    }

    /// Recover the [`ConvShape`] a single-layer (image, filter) spec
    /// encodes, under the paper's input convention `WI = σw·wO + wF`, and
    /// validate that the spec is a consistent conv layer. This is the one
    /// authoritative inversion — the native backend and the integration
    /// tests all derive shapes through it.
    pub fn layer_shape(&self) -> Result<ConvShape> {
        if self.inputs.len() != 2 {
            return Err(err!(
                "'{}': expected (image, filter) inputs, got {}",
                self.key(),
                self.inputs.len()
            ));
        }
        let (i, f, o) = (&self.inputs[0], &self.inputs[1], &self.output);
        if i.len() != 4 || f.len() != 4 || o.len() != 4 {
            return Err(err!("'{}': inputs and output must be rank 4", self.key()));
        }
        if o[2] == 0 || o[3] == 0 || i[2] < f[2] || i[3] < f[3] {
            return Err(err!("'{}': inconsistent spatial dims", self.key()));
        }
        let s_w = (i[2] - f[2]) / o[2];
        let s_h = (i[3] - f[3]) / o[3];
        let s = ConvShape::new(
            o[0] as u64,
            f[0] as u64,
            f[1] as u64,
            o[2] as u64,
            o[3] as u64,
            f[2] as u64,
            f[3] as u64,
            s_w as u64,
            s_h as u64,
        );
        let want_input = vec![o[0], f[0], s.in_w() as usize, s.in_h() as usize];
        if s_w == 0 || s_h == 0 || *i != want_input || o[1] != f[1] {
            return Err(err!(
                "'{}': not a paper-convention conv layer (inputs {:?} / {:?}, \
                 output {:?})",
                self.key(),
                i,
                f,
                o
            ));
        }
        Ok(s)
    }
}

/// The whole manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub batch: usize,
    pub artifacts: Vec<ArtifactSpec>,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Manifest> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Manifest::parse(&text)
    }

    /// The built-in synthetic manifest: small single-layer conv specs
    /// (unit-stride 3×3 and 1×1, plus a strided 5×5) sized so the native
    /// backend answers in well under a millisecond per batch, each exposed
    /// through the kernel kinds the native backend implements (the 3×3 and
    /// strided 5×5 also as `"tiled"`, routing through the `kernels/`
    /// engine). This is what [`super::Runtime::builtin`] and the
    /// no-artifact serving path use.
    pub fn builtin(batch: u64) -> Manifest {
        assert!(batch >= 1);
        let unit3x3 = ConvShape::new(batch, 8, 16, 12, 12, 3, 3, 1, 1);
        let unit1x1 = ConvShape::new(batch, 16, 32, 14, 14, 1, 1, 1, 1);
        let unit5x5 = ConvShape::new(batch, 3, 12, 6, 6, 5, 5, 2, 2);
        Manifest {
            batch: batch as usize,
            artifacts: vec![
                ArtifactSpec::for_layer("unit3x3", "blocked", &unit3x3),
                ArtifactSpec::for_layer("unit3x3", "im2col", &unit3x3),
                ArtifactSpec::for_layer("unit3x3", "tiled", &unit3x3),
                ArtifactSpec::for_layer("unit1x1", "blocked", &unit1x1),
                ArtifactSpec::for_layer("unit5x5", "blocked", &unit5x5),
                ArtifactSpec::for_layer("unit5x5", "tiled", &unit5x5),
            ],
        }
    }

    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Json::parse(text).map_err(|e| err!("manifest: {e}"))?;
        let batch = v
            .get("batch")
            .as_u64()
            .ok_or_else(|| err!("manifest: missing 'batch'"))? as usize;
        let mut artifacts = Vec::new();
        for a in v
            .get("artifacts")
            .as_arr()
            .ok_or_else(|| err!("manifest: missing 'artifacts'"))?
        {
            let shape_list = |key: &str| -> Result<Vec<Vec<usize>>> {
                a.get(key)
                    .as_arr()
                    .ok_or_else(|| err!("manifest: missing '{key}'"))?
                    .iter()
                    .map(|s| {
                        s.as_arr()
                            .ok_or_else(|| err!("bad shape in '{key}'"))
                            .map(|dims| {
                                dims.iter()
                                    .map(|d| d.as_u64().unwrap_or(0) as usize)
                                    .collect()
                            })
                    })
                    .collect()
            };
            artifacts.push(ArtifactSpec {
                name: a
                    .get("name")
                    .as_str()
                    .ok_or_else(|| err!("artifact missing 'name'"))?
                    .to_string(),
                kind: a
                    .get("kind")
                    .as_str()
                    .ok_or_else(|| err!("artifact missing 'kind'"))?
                    .to_string(),
                path: a
                    .get("path")
                    .as_str()
                    .ok_or_else(|| err!("artifact missing 'path'"))?
                    .to_string(),
                inputs: shape_list("inputs")?,
                output: a
                    .get("output")
                    .as_arr()
                    .ok_or_else(|| err!("artifact missing 'output'"))?
                    .iter()
                    .map(|d| d.as_u64().unwrap_or(0) as usize)
                    .collect(),
                updates: a.get("updates").as_u64().unwrap_or(0),
            });
        }
        Ok(Manifest { batch, artifacts })
    }

    /// Find by `<name>/<kind>` key or bare name (if unique).
    pub fn find(&self, key: &str) -> Option<&ArtifactSpec> {
        if let Some(a) = self.artifacts.iter().find(|a| a.key() == key) {
            return Some(a);
        }
        let by_name: Vec<&ArtifactSpec> =
            self.artifacts.iter().filter(|a| a.name == key).collect();
        if by_name.len() == 1 {
            Some(by_name[0])
        } else {
            None
        }
    }

    pub fn keys(&self) -> Vec<String> {
        self.artifacts.iter().map(|a| a.key()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "batch": 4,
      "artifacts": [
        {"name": "unit3x3", "kind": "blocked", "path": "a.hlo.txt",
         "inputs": [[4,8,14,14],[8,16,3,3]], "output": [4,16,6,6],
         "updates": 663552},
        {"name": "unit3x3", "kind": "im2col", "path": "b.hlo.txt",
         "inputs": [[4,8,14,14],[8,16,3,3]], "output": [4,16,6,6],
         "updates": 663552}
      ]
    }"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.batch, 4);
        assert_eq!(m.artifacts.len(), 2);
        assert_eq!(m.artifacts[0].inputs[0], vec![4, 8, 14, 14]);
        assert_eq!(m.artifacts[0].output, vec![4, 16, 6, 6]);
        assert_eq!(m.artifacts[0].updates, 663552);
    }

    #[test]
    fn find_by_key_and_ambiguous_name() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert!(m.find("unit3x3/blocked").is_some());
        assert!(m.find("unit3x3/im2col").is_some());
        // bare name is ambiguous (two kinds) -> None
        assert!(m.find("unit3x3").is_none());
        assert!(m.find("missing").is_none());
    }

    #[test]
    fn parse_rejects_missing_fields() {
        assert!(Manifest::parse("{}").is_err());
        assert!(Manifest::parse(r#"{"batch": 1}"#).is_err());
        assert!(Manifest::parse(
            r#"{"batch": 1, "artifacts": [{"kind": "x"}]}"#
        )
        .is_err());
    }

    #[test]
    fn builtin_manifest_is_well_formed() {
        let m = Manifest::builtin(4);
        assert_eq!(m.batch, 4);
        assert!(m.find("unit3x3/blocked").is_some());
        assert!(m.find("unit3x3/im2col").is_some());
        assert!(m.find("unit3x3/tiled").is_some());
        assert!(m.find("unit5x5/tiled").is_some());
        assert!(m.find("unit1x1/blocked").is_some());
        for a in &m.artifacts {
            assert_eq!(a.inputs.len(), 2);
            assert_eq!(a.output.len(), 4);
            assert_eq!(a.inputs[0][0], 4, "batch dim");
            assert!(a.updates > 0);
        }
        // keys are unique
        let mut keys = m.keys();
        keys.sort();
        keys.dedup();
        assert_eq!(keys.len(), m.artifacts.len());
    }

    #[test]
    fn real_manifest_if_present() {
        // integration check against the actual build output when available
        let p = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/manifest.json");
        if p.exists() {
            let m = Manifest::load(&p).unwrap();
            assert!(!m.artifacts.is_empty());
            for a in &m.artifacts {
                assert_eq!(a.output.len(), 4);
                assert!(!a.inputs.is_empty());
            }
        }
    }
}
