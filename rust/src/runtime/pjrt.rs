//! The PJRT/XLA execution backend (cargo feature `pjrt`).
//!
//! Loads AOT artifacts (`artifacts/*.hlo.txt`, produced once by
//! `python/compile/aot.py`) and executes them on the PJRT CPU client through
//! the external `xla` crate. Python is never on this path.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 serializes HloModuleProto with
//! 64-bit instruction ids which xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).
//!
//! PJRT handles are not `Send`; keep a [`PjrtBackend`]-driven
//! [`super::Runtime`] on the thread that created it (the coordinator's
//! server constructs its runtime inside the executor thread for exactly
//! this reason).

use std::path::Path;

use crate::conv::Tensor4;
use crate::err;
use crate::util::error::Result;

use super::backend::{ExecBackend, Executable};
use super::manifest::ArtifactSpec;

/// One PJRT CPU client, shared by every artifact it compiles.
pub struct PjrtBackend {
    client: xla::PjRtClient,
}

impl PjrtBackend {
    pub fn new() -> Result<PjrtBackend> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| err!("pjrt cpu client: {e:?}"))?;
        Ok(PjrtBackend { client })
    }
}

impl ExecBackend for PjrtBackend {
    fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn load(
        &mut self,
        spec: &ArtifactSpec,
        path: Option<&Path>,
    ) -> Result<Box<dyn Executable>> {
        let path = path.ok_or_else(|| {
            err!("pjrt backend needs an artifact directory for '{}'", spec.key())
        })?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .map_err(|e| err!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| err!("compile {}: {e:?}", path.display()))?;
        Ok(Box::new(PjrtExec { spec: spec.clone(), exe }))
    }
}

struct PjrtExec {
    spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Executable for PjrtExec {
    fn execute(&self, inputs: &[&Tensor4]) -> Result<Tensor4> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, t) in inputs.iter().enumerate() {
            let dims: Vec<i64> = t.dims.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(&t.data)
                .reshape(&dims)
                .map_err(|e| err!("reshape input {i}: {e:?}"))?;
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| err!("execute '{}': {e:?}", self.spec.key()))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| err!("fetch result: {e:?}"))?;
        // aot.py lowers with return_tuple=True: the output is a 1-tuple.
        let out = lit.to_tuple1().map_err(|e| err!("untuple: {e:?}"))?;
        let data = out
            .to_vec::<f32>()
            .map_err(|e| err!("result to_vec: {e:?}"))?;
        let od = &self.spec.output;
        if data.len() != od.iter().product::<usize>() {
            return Err(err!(
                "result has {} elements, manifest says {:?}",
                data.len(),
                od
            ));
        }
        Ok(Tensor4 { dims: [od[0], od[1], od[2], od[3]], data })
    }
}
