//! The execution layer (L3 runtime), behind a pluggable [`ExecBackend`].
//!
//! A [`Runtime`] owns a manifest of artifacts, a backend that prepares and
//! runs them, and a cache of loaded executables. The default backend is
//! [`native::NativeBackend`], which executes single-layer conv specs with
//! the crate's own kernels — `cargo build` and every test work with no
//! `artifacts/` directory, no Python and no external crates. The original
//! PJRT/XLA path lives in `pjrt.rs` behind the `pjrt` cargo feature and
//! slots in through the same trait.
//!
//! Construction:
//!
//! * [`Runtime::new`] — artifact directory, default backend (native, or
//!   PJRT when the `pjrt` feature is enabled);
//! * [`Runtime::native`] — artifact directory, native backend regardless of
//!   features;
//! * [`Runtime::builtin`] — no directory at all: the synthetic
//!   [`Manifest::builtin`] over the native backend;
//! * [`Runtime::with_manifest`] / [`Runtime::with_backend`] — explicit
//!   wiring for tests and future backends.

pub mod backend;
pub mod fallback;
pub mod hlostats;
pub mod manifest;
pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

pub use backend::{ExecBackend, Executable, FaultStats};
pub use fallback::FallbackExec;
pub use hlostats::{analyze_file, analyze_text, HloStats};
pub use manifest::{ArtifactSpec, Manifest, NetworkSpec, NetworkStage};
pub use native::NativeBackend;

use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use crate::conv::Tensor4;
use crate::err;
use crate::obs::{self, jf, js};
use crate::util::error::{Context, Result};

/// A prepared executable plus its IO metadata.
pub struct LoadedArtifact {
    pub spec: ArtifactSpec,
    exe: Box<dyn Executable>,
}

/// The runtime: one backend, a manifest, and the loaded-artifact cache.
pub struct Runtime {
    backend: Box<dyn ExecBackend>,
    /// Backing artifact directory; `None` for in-memory manifests.
    dir: Option<PathBuf>,
    manifest: Manifest,
    loaded: HashMap<String, LoadedArtifact>,
}

impl Runtime {
    /// Create a runtime over an artifact directory (reads `manifest.json`,
    /// loads nothing yet) on the default backend: native, or PJRT when the
    /// `pjrt` feature is enabled.
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Runtime> {
        #[cfg(feature = "pjrt")]
        let backend: Box<dyn ExecBackend> = Box::new(pjrt::PjrtBackend::new()?);
        #[cfg(not(feature = "pjrt"))]
        let backend: Box<dyn ExecBackend> = Box::new(NativeBackend::new());
        Runtime::with_backend(artifact_dir, backend)
    }

    /// Artifact-directory runtime forced onto the native backend.
    pub fn native(artifact_dir: impl AsRef<Path>) -> Result<Runtime> {
        Runtime::with_backend(artifact_dir, Box::new(NativeBackend::new()))
    }

    /// Fully in-memory native runtime over [`Manifest::builtin`] — works
    /// with no artifact directory and no PJRT (the zero-setup path the e2e
    /// tests, the serving benches and `convbound serve` use).
    pub fn builtin() -> Runtime {
        Runtime::with_manifest(
            Manifest::builtin(manifest::BUILTIN_BATCH),
            Box::new(NativeBackend::new()),
        )
    }

    /// Runtime over an explicit manifest with no backing directory.
    pub fn with_manifest(manifest: Manifest, backend: Box<dyn ExecBackend>) -> Runtime {
        Runtime { backend, dir: None, manifest, loaded: HashMap::new() }
    }

    /// Runtime over `artifact_dir`'s `manifest.json` with an explicit
    /// backend.
    pub fn with_backend(
        artifact_dir: impl AsRef<Path>,
        backend: Box<dyn ExecBackend>,
    ) -> Result<Runtime> {
        let dir = artifact_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        Ok(Runtime { backend, dir: Some(dir), manifest, loaded: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.backend.platform()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Prepare one artifact by key (`<name>/<kind>`), caching the result.
    /// The freshly inserted entry is returned directly — no second hash
    /// lookup on either the hit or the miss path. `"network"` (fused
    /// forward pipeline) and `"training"` (fused backward sweep) kinds
    /// whose manifest carries a matching [`NetworkSpec`] load through
    /// [`ExecBackend::load_network`] on backends that opt in
    /// ([`ExecBackend::supports_networks`]); otherwise they fall back to
    /// the backend's file loader (the AOT/PJRT route, which executes the
    /// lowered HLO module rather than the native fused pipeline).
    pub fn load(&mut self, key: &str) -> Result<&LoadedArtifact> {
        match self.loaded.entry(key.to_string()) {
            Entry::Occupied(hit) => Ok(hit.into_mut()),
            Entry::Vacant(slot) => {
                let spec = self
                    .manifest
                    .find(key)
                    .ok_or_else(|| err!("artifact '{key}' not in manifest"))?
                    .clone();
                let is_pipeline =
                    spec.kind == "network" || spec.kind == "training";
                let net = if is_pipeline && self.backend.supports_networks() {
                    self.manifest.network(&spec.name).cloned()
                } else {
                    None
                };
                let exe = match net {
                    Some(net) => self.backend.load_network(&net, &spec)?,
                    // single-layer kinds, and legacy file-based network
                    // artifacts whose manifest carries no NetworkSpec
                    // (the AOT/PJRT route): the backend's file loader
                    None => {
                        let path = self.dir.as_ref().map(|d| d.join(&spec.path));
                        self.backend.load(&spec, path.as_deref())?
                    }
                };
                if obs::enabled() {
                    obs::event(
                        obs::kind::ARTIFACT_LOAD,
                        &[
                            ("key", js(key)),
                            ("artifact", js(&spec.kind)),
                            ("platform", js(&self.backend.platform())),
                        ],
                    );
                }
                Ok(slot.insert(LoadedArtifact { spec, exe }))
            }
        }
    }

    /// Prepare every artifact in the manifest up front.
    pub fn load_all(&mut self) -> Result<()> {
        for k in self.manifest.keys() {
            self.load(&k)?;
        }
        Ok(())
    }

    /// Execute a loaded artifact on host tensors.
    ///
    /// Input tensor shapes must match the manifest entry; the output is
    /// returned as a [`Tensor4`] of the manifest's output shape.
    pub fn run(&self, key: &str, inputs: &[&Tensor4]) -> Result<Tensor4> {
        let art = self
            .loaded
            .get(key)
            .ok_or_else(|| err!("artifact '{key}' not loaded"))?;
        art.run(inputs)
    }

    /// Like [`Runtime::run`], but with shared tensors: instrumented
    /// backends (native `"tiled"`/`"network"`) hand the `Arc`s straight to
    /// their worker pools instead of cloning each operand per request —
    /// the zero-copy serving hot path [`crate::coordinator::ConvServer`]
    /// uses.
    pub fn run_arc(&self, key: &str, inputs: &[Arc<Tensor4>]) -> Result<Tensor4> {
        let art = self
            .loaded
            .get(key)
            .ok_or_else(|| err!("artifact '{key}' not loaded"))?;
        art.run_arc(inputs)
    }

    /// `load` + `run` in one call, reusing the entry `load` returns.
    pub fn run_loading(&mut self, key: &str, inputs: &[&Tensor4]) -> Result<Tensor4> {
        self.load(key)?.run(inputs)
    }

    /// Cumulative measured word traffic of a loaded artifact, when its
    /// executable is instrumented (the native `"tiled"` and `"network"`
    /// kinds); `None` for unloaded or uninstrumented artifacts.
    pub fn traffic(&self, key: &str) -> Option<crate::kernels::Traffic> {
        self.loaded.get(key).and_then(|a| a.traffic())
    }

    /// Per-stage measured traffic of a loaded `"network"` artifact (stage
    /// order); `None` for unloaded or single-layer artifacts.
    pub fn stage_traffic(&self, key: &str) -> Option<Vec<crate::kernels::Traffic>> {
        self.loaded.get(key).and_then(|a| a.exe.stage_traffic())
    }

    /// Per-stage words a loaded `"network"` artifact served from the fused
    /// executor's sliding-window halo cache; `None` for unloaded or
    /// single-layer artifacts.
    pub fn halo_words(&self, key: &str) -> Option<Vec<u64>> {
        self.loaded.get(key).and_then(|a| a.exe.halo_words())
    }

    /// Aggregate fault counters (caught panics, degraded runs) across
    /// every loaded artifact whose executable reports them — the server
    /// folds this into [`crate::coordinator::ServerStats`] at shutdown.
    pub fn fault_stats(&self) -> FaultStats {
        let mut total = FaultStats::default();
        for a in self.loaded.values() {
            if let Some(s) = a.exe.fault_stats() {
                total.add(s);
            }
        }
        total
    }
}

impl LoadedArtifact {
    /// Measured word traffic, when the executable is instrumented.
    pub fn traffic(&self) -> Option<crate::kernels::Traffic> {
        self.exe.traffic()
    }

    /// Validate input arity and shapes against the manifest spec.
    fn check_inputs(&self, dims: &[&[usize; 4]]) -> Result<()> {
        if dims.len() != self.spec.inputs.len() {
            return Err(err!(
                "artifact '{}' wants {} inputs, got {}",
                self.spec.key(),
                self.spec.inputs.len(),
                dims.len()
            ));
        }
        for (i, d) in dims.iter().enumerate() {
            let want = &self.spec.inputs[i];
            let have: Vec<usize> = d.to_vec();
            if &have != want {
                return Err(err!(
                    "artifact '{}' input {i}: shape {have:?} != manifest {want:?}",
                    self.spec.key()
                ));
            }
        }
        Ok(())
    }

    /// Validate the produced output shape against the manifest spec.
    fn check_output(&self, out: Tensor4) -> Result<Tensor4> {
        if out.dims.to_vec() != self.spec.output {
            return Err(err!(
                "artifact '{}': backend produced shape {:?}, manifest says {:?}",
                self.spec.key(),
                out.dims,
                self.spec.output
            ));
        }
        Ok(out)
    }

    /// Execute with host tensors, validating input and output shapes
    /// against the manifest spec (backend-agnostic).
    pub fn run(&self, inputs: &[&Tensor4]) -> Result<Tensor4> {
        let dims: Vec<&[usize; 4]> = inputs.iter().map(|t| &t.dims).collect();
        self.check_inputs(&dims)?;
        let out = self.traced_exec(|| self.exe.execute(inputs))?;
        self.check_output(out)
    }

    /// Execute with shared host tensors (same validation as
    /// [`LoadedArtifact::run`]); instrumented backends skip the per-call
    /// operand clone.
    pub fn run_arc(&self, inputs: &[Arc<Tensor4>]) -> Result<Tensor4> {
        let dims: Vec<&[usize; 4]> = inputs.iter().map(|t| &t.dims).collect();
        self.check_inputs(&dims)?;
        let out = self.traced_exec(|| self.exe.execute_arc(inputs))?;
        self.check_output(out)
    }

    /// Run one execution under an `exec` trace span (exec start/end with
    /// the artifact key and measured seconds). The disabled path is one
    /// branch.
    fn traced_exec(
        &self,
        f: impl FnOnce() -> Result<Tensor4>,
    ) -> Result<Tensor4> {
        if !obs::enabled() {
            return f();
        }
        let scope = obs::scope(
            obs::kind::EXEC,
            &[("key", js(&self.spec.key())), ("artifact", js(&self.spec.kind))],
        );
        let t0 = std::time::Instant::now();
        let out = f();
        let secs = t0.elapsed().as_secs_f64();
        scope.end(&[("secs", jf(secs)), ("ok", crate::obs::jb(out.is_ok()))]);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_runtime_loads_and_caches() {
        let mut rt = Runtime::builtin();
        assert_eq!(rt.platform(), "native-cpu");
        let key = "unit3x3/blocked";
        let spec = rt.load(key).expect("load").spec.clone();
        assert_eq!(spec.key(), key);
        // second load is a cache hit returning the same spec
        assert_eq!(rt.load(key).expect("cached").spec, spec);
        rt.load_all().expect("all builtin artifacts load natively");
    }

    #[test]
    fn run_validates_shapes() {
        let mut rt = Runtime::builtin();
        let key = "unit3x3/blocked";
        let spec = rt.load(key).unwrap().spec.clone();
        let xd = &spec.inputs[0];
        let x = Tensor4::randn([xd[0], xd[1], xd[2], xd[3]], 1);
        assert!(rt.run(key, &[&x]).is_err(), "one input must fail");
        let bad = Tensor4::zeros([1, 1, 1, 1]);
        assert!(rt.run(key, &[&x, &bad]).is_err(), "bad filter shape");
        assert!(rt.run("missing/kind", &[]).is_err(), "unknown key");
    }

    #[test]
    fn tiled_artifact_reports_traffic() {
        let mut rt = Runtime::builtin();
        let key = "unit3x3/tiled";
        rt.load(key).expect("load tiled");
        // instrumented but not yet run: zero counters
        assert_eq!(
            rt.traffic(key).expect("tiled is instrumented").total(),
            0
        );
        // the naive kind is uninstrumented
        rt.load("unit3x3/blocked").expect("load blocked");
        assert!(rt.traffic("unit3x3/blocked").is_none());
        assert!(rt.traffic("never/loaded").is_none());

        let spec = rt.manifest().find(key).unwrap().clone();
        let (xd, wd) = (&spec.inputs[0], &spec.inputs[1]);
        let x = Tensor4::randn([xd[0], xd[1], xd[2], xd[3]], 1);
        let w = Tensor4::randn([wd[0], wd[1], wd[2], wd[3]], 2);
        rt.run(key, &[&x, &w]).expect("run tiled");
        let t = rt.traffic(key).expect("snapshot");
        assert!(t.input_words > 0 && t.filter_words > 0);
        assert_eq!(t.output_words as usize, spec.output.iter().product::<usize>());
    }

    #[test]
    fn network_artifact_runs_and_reports_stage_traffic() {
        let mut rt = Runtime::builtin();
        let key = "tiny_resnet/network";
        let spec = rt.load(key).expect("load network").spec.clone();
        assert_eq!(spec.inputs.len(), 4, "image + 3 filters");
        // not yet run: instrumented with zero counters
        assert_eq!(rt.traffic(key).expect("instrumented").total(), 0);
        let inputs: Vec<Arc<Tensor4>> = spec
            .inputs
            .iter()
            .enumerate()
            .map(|(i, d)| {
                Arc::new(Tensor4::randn([d[0], d[1], d[2], d[3]], 30 + i as u64))
            })
            .collect();
        let out = rt.run_arc(key, &inputs).expect("run network");
        assert_eq!(out.dims.to_vec(), spec.output);
        let stages = rt.stage_traffic(key).expect("per-stage traffic");
        assert_eq!(stages.len(), 3);
        assert_eq!(
            stages[2].output_words as usize,
            spec.output.iter().product::<usize>()
        );
        // the fused executor is halo-instrumented (words may be zero when
        // the plan needs no h-tiling, but the counters must exist)
        assert!(rt.halo_words(key).is_some());
        // single-layer artifacts expose no stage traffic or halo counters
        rt.load("unit3x3/tiled").expect("load tiled");
        assert!(rt.stage_traffic("unit3x3/tiled").is_none());
        assert!(rt.halo_words("unit3x3/tiled").is_none());
        // the non-arc entry point agrees with the arc one
        let refs: Vec<&Tensor4> = inputs.iter().map(|a| a.as_ref()).collect();
        let again = rt.run(key, &refs).expect("run network via refs");
        assert_eq!(again.max_abs_diff(&out), 0.0);
    }

    #[test]
    fn training_artifact_runs_the_backward_sweep() {
        let mut rt = Runtime::builtin();
        let key = "tiny_resnet/training";
        let spec = rt.load(key).expect("load training").spec.clone();
        assert_eq!(spec.inputs.len(), 4, "loss gradient + 3 filters");
        // instrumented but not yet run: zero counters
        assert_eq!(rt.traffic(key).expect("instrumented").total(), 0);
        let inputs: Vec<Arc<Tensor4>> = spec
            .inputs
            .iter()
            .enumerate()
            .map(|(i, d)| {
                Arc::new(Tensor4::randn([d[0], d[1], d[2], d[3]], 60 + i as u64))
            })
            .collect();
        let out = rt.run_arc(key, &inputs).expect("run training sweep");
        assert_eq!(out.dims.to_vec(), spec.output);
        let stages = rt.stage_traffic(key).expect("per-stage traffic");
        assert_eq!(stages.len(), 3);
        assert!(rt.halo_words(key).is_some());
        // the image gradient has the forward network's input geometry
        let fwd = rt.manifest().find("tiny_resnet/network").unwrap();
        assert_eq!(spec.output, fwd.inputs[0]);
    }

    #[test]
    fn run_arc_validates_shapes() {
        let mut rt = Runtime::builtin();
        let key = "unit3x3/tiled";
        let spec = rt.load(key).unwrap().spec.clone();
        let xd = &spec.inputs[0];
        let x = Arc::new(Tensor4::randn([xd[0], xd[1], xd[2], xd[3]], 1));
        assert!(rt.run_arc(key, &[Arc::clone(&x)]).is_err(), "arity");
        let bad = Arc::new(Tensor4::zeros([1, 1, 1, 1]));
        assert!(rt.run_arc(key, &[x, bad]).is_err(), "bad filter shape");
    }

    // Artifact-directory round-trip tests live in
    // rust/tests/runtime_roundtrip.rs.
}
