//! PJRT execution layer (L3 runtime).
//!
//! Loads AOT artifacts (`artifacts/*.hlo.txt`, produced once by
//! `python/compile/aot.py`) and executes them on the PJRT CPU client through
//! the `xla` crate. Python is never on this path.
//!
//! Interchange is HLO *text*: jax ≥ 0.5 serializes HloModuleProto with
//! 64-bit instruction ids which xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md).

pub mod hlostats;
pub mod manifest;

pub use hlostats::{analyze_file, analyze_text, HloStats};
pub use manifest::{ArtifactSpec, Manifest};

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::conv::Tensor4;

/// A compiled executable plus its IO metadata.
pub struct LoadedArtifact {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

/// The runtime: one PJRT client and a set of compiled artifacts.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
    loaded: HashMap<String, LoadedArtifact>,
}

impl Runtime {
    /// Create a CPU runtime over an artifact directory (reads
    /// `manifest.json`, compiles nothing yet).
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = artifact_dir.as_ref().to_path_buf();
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let manifest = Manifest::load(dir.join("manifest.json"))
            .with_context(|| format!("loading manifest from {}", dir.display()))?;
        Ok(Runtime { client, dir, manifest, loaded: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile one artifact by key (`<name>/<kind>`), caching the result.
    pub fn load(&mut self, key: &str) -> Result<&LoadedArtifact> {
        if !self.loaded.contains_key(key) {
            let spec = self
                .manifest
                .find(key)
                .ok_or_else(|| anyhow!("artifact '{key}' not in manifest"))?
                .clone();
            let path = self.dir.join(&spec.path);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e:?}", path.display()))?;
            self.loaded.insert(key.to_string(), LoadedArtifact { spec, exe });
        }
        Ok(&self.loaded[key])
    }

    /// Compile every artifact in the manifest up front.
    pub fn load_all(&mut self) -> Result<()> {
        let keys: Vec<String> =
            self.manifest.artifacts.iter().map(|a| a.key()).collect();
        for k in keys {
            self.load(&k)?;
        }
        Ok(())
    }

    /// Execute a loaded artifact on host tensors.
    ///
    /// Input tensor shapes must match the manifest entry; the single tuple
    /// output is unwrapped and returned as a [`Tensor4`].
    pub fn run(&self, key: &str, inputs: &[&Tensor4]) -> Result<Tensor4> {
        let art = self
            .loaded
            .get(key)
            .ok_or_else(|| anyhow!("artifact '{key}' not loaded"))?;
        art.run(inputs)
    }

    /// `load` + `run` in one call.
    pub fn run_loading(&mut self, key: &str, inputs: &[&Tensor4]) -> Result<Tensor4> {
        self.load(key)?;
        self.run(key, inputs)
    }
}

impl LoadedArtifact {
    /// Execute with host tensors, validating shapes against the manifest.
    pub fn run(&self, inputs: &[&Tensor4]) -> Result<Tensor4> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(anyhow!(
                "artifact '{}' wants {} inputs, got {}",
                self.spec.key(), self.spec.inputs.len(), inputs.len()
            ));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, t) in inputs.iter().enumerate() {
            let want = &self.spec.inputs[i];
            let have: Vec<usize> = t.dims.to_vec();
            if &have != want {
                return Err(anyhow!(
                    "artifact '{}' input {i}: shape {have:?} != manifest {want:?}",
                    self.spec.key()
                ));
            }
            let dims: Vec<i64> = t.dims.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(&t.data)
                .reshape(&dims)
                .map_err(|e| anyhow!("reshape input {i}: {e:?}"))?;
            literals.push(lit);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute '{}': {e:?}", self.spec.key()))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        // aot.py lowers with return_tuple=True: the output is a 1-tuple.
        let out = lit.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        let data = out
            .to_vec::<f32>()
            .map_err(|e| anyhow!("result to_vec: {e:?}"))?;
        let od = &self.spec.output;
        if data.len() != od.iter().product::<usize>() {
            return Err(anyhow!(
                "result has {} elements, manifest says {:?}",
                data.len(), od
            ));
        }
        Ok(Tensor4 { dims: [od[0], od[1], od[2], od[3]], data })
    }
}

#[cfg(test)]
mod tests {
    // Runtime round-trip tests live in rust/tests/runtime_roundtrip.rs —
    // they need the artifacts/ directory built by `make artifacts`.
}
