//! Per-layer execution planning: before anything runs, every layer gets a
//! communication-optimal plan — the §3.2 LP blocking for the cache/VMEM
//! level, the §5 GEMMINI tile for the accelerator level, and the Theorem
//! 2.1 bound diagnostics that justify them.

use crate::bounds::{sequential_bound_terms, SeqBoundTerms};
use crate::conv::{ConvShape, Precision};
use crate::gemmini::GemminiConfig;
use crate::tiling::{
    optimize_gemmini_tiling, sequential_blocking, vendor_tiling, GemminiTile,
    OptOptions, SeqBlocking,
};
use crate::util::threadpool::ThreadPool;

/// Everything the coordinator decides about one layer ahead of time.
#[derive(Debug, Clone)]
pub struct LayerPlan {
    pub name: String,
    pub shape: ConvShape,
    pub precision: Precision,
    /// cache/VMEM blocking (drives the Pallas BlockSpec choice at L1)
    pub blocking: SeqBlocking,
    /// accelerator tile (ours)
    pub gemmini: GemminiTile,
    /// accelerator tile (vendor baseline, for comparison reporting)
    pub gemmini_vendor: GemminiTile,
    /// Theorem 2.1 terms at the planning memory size
    pub bound: SeqBoundTerms,
    /// planning memory size in words
    pub mem_words: f64,
}

impl LayerPlan {
    /// Estimated communication of the planned blocking relative to the
    /// lower bound (≥ 1 up to model slack).
    pub fn blocking_ratio(&self) -> f64 {
        let tiles = self.shape.updates() as f64 / self.blocking.updates_per_tile();
        let vol = tiles * self.blocking.footprint_words(self.precision)
            + self.precision.p_o * self.shape.output_size() as f64;
        vol / self.bound.max().max(1.0)
    }
}

/// Plan one layer.
pub fn plan_layer(
    name: &str,
    shape: ConvShape,
    p: Precision,
    mem_words: f64,
    g: &GemminiConfig,
    opts: OptOptions,
) -> LayerPlan {
    LayerPlan {
        name: name.to_string(),
        shape,
        precision: p,
        blocking: sequential_blocking(&shape, p, mem_words),
        gemmini: optimize_gemmini_tiling(&shape, g, opts),
        gemmini_vendor: vendor_tiling(&shape, g),
        bound: sequential_bound_terms(&shape, p, mem_words),
        mem_words,
    }
}

/// Plans a whole network, fanning layer planning out over a thread pool
/// (the GEMMINI search dominates; layers are independent).
pub struct Planner {
    pub precision: Precision,
    pub mem_words: f64,
    pub gemmini: GemminiConfig,
    pub opts: OptOptions,
}

impl Default for Planner {
    fn default() -> Self {
        Planner {
            precision: Precision::uniform(),
            mem_words: 65536.0,
            gemmini: GemminiConfig::default(),
            opts: OptOptions::default(),
        }
    }
}

impl Planner {
    pub fn plan_network(&self, layers: &[(String, ConvShape)]) -> Vec<LayerPlan> {
        let pool = ThreadPool::new(
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).min(8),
        );
        let p = self.precision;
        let m = self.mem_words;
        let g = self.gemmini;
        let o = self.opts;
        pool.map(layers.to_vec(), move |(name, shape)| {
            plan_layer(&name, shape, p, m, &g, o)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::conv::resnet50_layers;

    #[test]
    fn plan_layer_is_consistent() {
        let l = resnet50_layers(64)[1];
        let plan = plan_layer(
            l.name, l.shape, Precision::uniform(), 65536.0,
            &GemminiConfig::default(), OptOptions::default(),
        );
        assert!(plan.blocking.fits(plan.precision, plan.mem_words));
        assert!(plan.gemmini.fits(&plan.shape, &GemminiConfig::default()));
        assert!(plan.blocking_ratio() >= 0.5, "{}", plan.blocking_ratio());
    }

    #[test]
    fn plan_network_parallel_matches_serial() {
        let layers: Vec<(String, ConvShape)> = resnet50_layers(32)
            .into_iter()
            .map(|l| (l.name.to_string(), l.shape))
            .collect();
        let planner = Planner::default();
        let plans = planner.plan_network(&layers);
        assert_eq!(plans.len(), layers.len());
        for (plan, (name, shape)) in plans.iter().zip(&layers) {
            assert_eq!(&plan.name, name);
            let serial = plan_layer(
                name, *shape, planner.precision, planner.mem_words,
                &planner.gemmini, planner.opts,
            );
            assert_eq!(plan.gemmini, serial.gemmini);
            assert_eq!(plan.blocking, serial.blocking);
        }
    }
}
