//! Batched convolution serving over the execution runtime.
//!
//! Architecture (single executor thread — backend handles are not
//! guaranteed `Send` (PJRT's are not), so the runtime lives on its own
//! thread and requests flow through channels):
//!
//! ```text
//! clients ── submit(image) ──► queue ──► batcher (size N, timeout) ──► backend
//!     ◄── per-request channel ◄── splitter ◄── output batch ◄────────────┘
//! ```
//!
//! Short batches (queue drained before N images arrived) are zero-padded;
//! padded slots are tracked in [`ServerStats`] since they waste MACs — the
//! batcher exists precisely to amortize the artifact's fixed batch size.
//!
//! Fault tolerance (DESIGN.md §12): admission is bounded by an optional
//! [`QueuePolicy`] — `Block` overflow exerts backpressure on submitters,
//! `Shed` fails fast with a typed `QueueFull` error and the queue depth
//! can never exceed capacity; per-request deadlines shed expired work at
//! dequeue, *before* it wastes a batch slot; a batch dispatch that panics
//! is caught (the executor and pool survive), retried once with a short
//! backoff, and only then failed — failing only that batch's requests
//! with typed errors. Every request therefore ends in exactly one of four
//! dispositions — `ok`, `failed`, `shed`, `expired` — and the shutdown
//! accounting identity `completed + failed + expired + shed == submitted`
//! is asserted.
//!
//! With the default native backend a server needs no artifacts at all:
//! [`ConvServer::start_builtin`] serves the synthetic
//! [`Manifest::builtin`] layers end to end,
//! [`ConvServer::start_builtin_network`] serves whole-network requests
//! through the fused pipeline (one filter tensor per stage, one submit per
//! image, the response is the final stage's activation slice), and
//! [`ConvServer::start_builtin_training`] serves the same pipeline's fused
//! *backward* sweep (`"training"` artifacts: submit a tail loss-gradient
//! slice, receive the head image-gradient slice) — the batcher, padding
//! accounting and zero-copy path are identical because a training artifact
//! has the same shape contract: one batched request operand plus fixed
//! per-stage weights.
//!
//! Zero-copy path: [`ConvServer::submit`] takes anything convertible into
//! an `Arc<Tensor4>`, weights are held in `Arc`s for the lifetime of the
//! executor, and each assembled batch reaches the backend through
//! [`Runtime::run_arc`] — the native `"tiled"`/`"network"` dispatch hands
//! those `Arc`s straight to its worker pool instead of cloning request
//! tensors per batch.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Condvar, Mutex, PoisonError};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use crate::conv::Tensor4;
use crate::err;
use crate::obs::{self, jb, jf, js, ju, SpanId, TraceSink};
use crate::runtime::{fallback, Manifest, Runtime};
use crate::testkit::faults;
use crate::util::error::{Error, ErrorKind, Result};
use crate::util::json::Json;
use crate::util::stats::percentile;

/// A finished request.
#[derive(Debug)]
pub struct ConvResponse {
    pub id: u64,
    /// (1, cO, wO, hO) slice of the batch output
    pub output: Tensor4,
    /// submit → response time
    pub latency: Duration,
}

struct Job {
    id: u64,
    /// trace span opened at enqueue (0 when tracing is off)
    span: SpanId,
    image: Arc<Tensor4>,
    enqueued: Instant,
    /// absolute expiry; the executor sheds the job at dequeue once past it
    deadline: Option<Instant>,
    reply: mpsc::Sender<Result<ConvResponse>>,
}

enum Msg {
    Run(Job),
    Stop,
}

/// How a bounded admission queue handles a submit at capacity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Overflow {
    /// `submit` blocks until a slot frees — backpressure into the caller.
    Block,
    /// `submit` fails fast with a typed `QueueFull` error.
    Shed,
}

/// Bounded admission queue: at most `capacity` submitted-but-undrained
/// requests, with `overflow` deciding what a full queue does to `submit`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct QueuePolicy {
    pub capacity: u64,
    pub overflow: Overflow,
}

/// Serving options beyond the artifact key and weights.
#[derive(Debug, Clone, Copy)]
pub struct ServerOptions {
    /// Bounded admission queue; `None` = unbounded (the legacy behavior).
    pub queue: Option<QueuePolicy>,
    /// Per-request deadline measured from submit. Expired requests are
    /// shed at dequeue with a typed `DeadlineExceeded` error, before they
    /// waste a batch slot.
    pub deadline: Option<Duration>,
    /// How long the batcher waits to fill a batch once it holds at least
    /// one request.
    pub linger: Duration,
}

impl Default for ServerOptions {
    fn default() -> ServerOptions {
        ServerOptions {
            queue: None,
            deadline: None,
            linger: Duration::from_millis(2),
        }
    }
}

/// Aggregate serving statistics, plus per-request latency percentiles
/// and the peak batching-queue depth — both computed from the samples
/// the executor records (via [`crate::util::stats::percentile`]), not
/// estimated.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ServerStats {
    /// Requests executed and replied to.
    pub requests: u64,
    /// Requests accepted but failed: their batch dispatch failed after a
    /// retry, or they were still queued at shutdown.
    pub failed: u64,
    /// Requests rejected at submit by a full `Shed` queue.
    pub shed: u64,
    /// Requests accepted but past their deadline at dequeue.
    pub expired: u64,
    /// Worker panics caught (per failed attempt) — by the native
    /// backend's fallback wrapper or the executor's dispatch guard. The
    /// process survived every one of them.
    pub panicked: u64,
    /// Executions that degraded to a simpler verified path.
    pub degraded: u64,
    pub batches: u64,
    pub padded_slots: u64,
    pub total_exec_secs: f64,
    pub latency_p50_ms: f64,
    pub latency_p95_ms: f64,
    pub latency_p99_ms: f64,
    /// Max submitted-but-not-yet-drained requests observed at any enqueue.
    pub peak_queue_depth: u64,
}

/// Where the executor thread gets its runtime. Backend handles may not be
/// `Send`, so only this description crosses into the thread; the runtime is
/// constructed there.
enum Source {
    Dir(PathBuf),
    Builtin,
}

impl Source {
    fn manifest(&self) -> Result<Manifest> {
        match self {
            Source::Dir(d) => Manifest::load(d.join("manifest.json")),
            // the same constant Runtime::builtin uses, so the shapes
            // validated here are exactly the shapes the executor runs
            Source::Builtin => {
                Ok(Manifest::builtin(crate::runtime::manifest::BUILTIN_BATCH))
            }
        }
    }

    fn runtime(&self) -> Result<Runtime> {
        match self {
            Source::Dir(d) => Runtime::new(d),
            Source::Builtin => Ok(Runtime::builtin()),
        }
    }
}

/// Close a request's span with a terminal disposition and reply with a
/// typed error. A dropped reply receiver is fine.
fn reject_job(trace: &TraceSink, job: Job, disposition: &str, e: &Error) {
    trace.span_close(
        obs::kind::REQUEST,
        job.span,
        &[
            ("req", ju(job.id)),
            ("disposition", js(disposition)),
            ("cause", js(&e.to_string())),
        ],
    );
    let _ = job.reply.send(Err(e.clone()));
}

fn job_expired(job: &Job) -> bool {
    job.deadline.is_some_and(|d| Instant::now() >= d)
}

/// One guarded dispatch attempt: a panic unwinding out of the runtime is
/// caught here (counted + traced), so the executor thread survives it.
fn dispatch_once(
    rt: &Runtime,
    key: &str,
    operands: &[Arc<Tensor4>],
    trace: &TraceSink,
    caught_panics: &mut u64,
) -> Result<Tensor4> {
    match catch_unwind(AssertUnwindSafe(|| rt.run_arc(key, operands))) {
        Ok(r) => r,
        Err(p) => {
            *caught_panics += 1;
            let e = fallback::panic_to_error(p);
            if trace.enabled() {
                trace.event(
                    obs::kind::WORKER_PANIC,
                    &[
                        ("key", js(key)),
                        ("path", js("dispatch")),
                        ("cause", js(&e.to_string())),
                    ],
                );
            }
            Err(e)
        }
    }
}

/// Sets `closed` and wakes blocked submitters when the executor exits by
/// ANY path (including an unwind), so `Overflow::Block` admission can
/// never hang on a dead executor.
struct ClosedOnExit {
    closed: Arc<AtomicBool>,
    gate: Arc<(Mutex<()>, Condvar)>,
}

impl Drop for ClosedOnExit {
    fn drop(&mut self) {
        self.closed.store(true, Ordering::SeqCst);
        let (lock, cv) = &*self.gate;
        let _g = lock.lock().unwrap_or_else(PoisonError::into_inner);
        cv.notify_all();
    }
}

/// Handle to the executor thread.
pub struct ConvServer {
    tx: mpsc::Sender<Msg>,
    handle: Option<thread::JoinHandle<Result<ServerStats>>>,
    /// shared with the executor: total requests submitted, including shed
    /// ones (the shutdown path asserts
    /// completed + failed + expired + shed == this)
    next_id: Arc<AtomicU64>,
    /// submitted-but-not-yet-drained requests (incremented at admission,
    /// decremented when the executor pulls the job off the channel)
    queue_depth: Arc<AtomicU64>,
    /// max queue depth ever observed at an enqueue
    peak_depth: Arc<AtomicU64>,
    /// requests rejected at submit by a full `Shed` queue
    shed: Arc<AtomicU64>,
    /// true once the executor has exited (or shutdown began); `Block`
    /// admission gives up with a typed `Shutdown` error
    closed: Arc<AtomicBool>,
    /// wakes `Block`-mode submitters when a slot frees or the server closes
    gate: Arc<(Mutex<()>, Condvar)>,
    policy: Option<QueuePolicy>,
    deadline: Option<Duration>,
    trace: TraceSink,
    batch: usize,
    in_dims: [usize; 4],
}

impl ConvServer {
    /// Start a server for one single-layer artifact `key` from an artifact
    /// directory, with fixed filter weights. `linger` bounds how long the
    /// batcher waits to fill a batch once it holds at least one request.
    pub fn start(
        artifact_dir: impl AsRef<Path>,
        key: &str,
        weights: Tensor4,
        linger: Duration,
    ) -> Result<ConvServer> {
        ConvServer::start_source(
            Source::Dir(artifact_dir.as_ref().to_path_buf()),
            key,
            vec![weights],
            ServerOptions { linger, ..ServerOptions::default() },
            TraceSink::global(),
        )
    }

    /// Start a server over the built-in native manifest — no artifact
    /// directory required (keys: `unit3x3/blocked`, `unit3x3/im2col`,
    /// `unit1x1/blocked`, `unit5x5/blocked`).
    pub fn start_builtin(
        key: &str,
        weights: Tensor4,
        linger: Duration,
    ) -> Result<ConvServer> {
        ConvServer::start_source(
            Source::Builtin,
            key,
            vec![weights],
            ServerOptions { linger, ..ServerOptions::default() },
            TraceSink::global(),
        )
    }

    /// Start a built-in server with explicit [`ServerOptions`] (bounded
    /// queue, deadline, linger). Takes one weight tensor per artifact
    /// filter input, so it serves single-layer, network and training keys
    /// alike.
    pub fn start_builtin_opts(
        key: &str,
        weights: Vec<Tensor4>,
        opts: ServerOptions,
    ) -> Result<ConvServer> {
        ConvServer::start_source(Source::Builtin, key, weights, opts, TraceSink::global())
    }

    /// [`ConvServer::start_builtin_opts`] over an artifact directory.
    pub fn start_opts(
        artifact_dir: impl AsRef<Path>,
        key: &str,
        weights: Vec<Tensor4>,
        opts: ServerOptions,
    ) -> Result<ConvServer> {
        ConvServer::start_source(
            Source::Dir(artifact_dir.as_ref().to_path_buf()),
            key,
            weights,
            opts,
            TraceSink::global(),
        )
    }

    /// Start a built-in server with an explicit [`TraceSink`] instead of
    /// the process-global one — the wiring tests and embedders use to
    /// capture exactly one server's events. Takes one weight tensor per
    /// artifact filter input, so it serves single-layer, network and
    /// training keys alike.
    pub fn start_builtin_traced(
        key: &str,
        weights: Vec<Tensor4>,
        linger: Duration,
        trace: TraceSink,
    ) -> Result<ConvServer> {
        ConvServer::start_source(
            Source::Builtin,
            key,
            weights,
            ServerOptions { linger, ..ServerOptions::default() },
            trace,
        )
    }

    /// [`ConvServer::start_builtin_traced`] with explicit [`ServerOptions`].
    pub fn start_builtin_traced_opts(
        key: &str,
        weights: Vec<Tensor4>,
        opts: ServerOptions,
        trace: TraceSink,
    ) -> Result<ConvServer> {
        ConvServer::start_source(Source::Builtin, key, weights, opts, trace)
    }

    /// Start a server for a whole-network artifact from a directory: one
    /// fixed filter tensor per stage, requests batched exactly like the
    /// single-layer path, responses carrying the final stage's activation.
    pub fn start_network(
        artifact_dir: impl AsRef<Path>,
        key: &str,
        weights: Vec<Tensor4>,
        linger: Duration,
    ) -> Result<ConvServer> {
        ConvServer::start_source(
            Source::Dir(artifact_dir.as_ref().to_path_buf()),
            key,
            weights,
            ServerOptions { linger, ..ServerOptions::default() },
            TraceSink::global(),
        )
    }

    /// Start a whole-network server over the built-in native manifest
    /// (key: `tiny_resnet/network`, one filter per stage).
    pub fn start_builtin_network(
        key: &str,
        weights: Vec<Tensor4>,
        linger: Duration,
    ) -> Result<ConvServer> {
        ConvServer::start_source(
            Source::Builtin,
            key,
            weights,
            ServerOptions { linger, ..ServerOptions::default() },
            TraceSink::global(),
        )
    }

    /// Start a gradient server over the built-in native manifest (key:
    /// `tiny_resnet/training`, one fixed filter per stage). Requests are
    /// tail loss-gradient slices `(1, cO, wO, hO)`; each response is the
    /// head image-gradient slice the fused backward sweep produces —
    /// bitwise identical to chaining the per-stage dInput oracles.
    pub fn start_builtin_training(
        key: &str,
        weights: Vec<Tensor4>,
        linger: Duration,
    ) -> Result<ConvServer> {
        ConvServer::start_source(
            Source::Builtin,
            key,
            weights,
            ServerOptions { linger, ..ServerOptions::default() },
            TraceSink::global(),
        )
    }

    fn start_source(
        source: Source,
        key: &str,
        weights: Vec<Tensor4>,
        opts: ServerOptions,
        trace: TraceSink,
    ) -> Result<ConvServer> {
        // Validate shapes from the manifest up front (plain data,
        // Send-safe); the runtime itself is created *inside* the executor
        // thread — its backend handles may not be Send.
        let manifest = source.manifest()?;
        let spec = manifest
            .find(key)
            .ok_or_else(|| err!("artifact '{key}' not found"))?
            .clone();
        if spec.inputs.len() < 2 {
            return Err(err!("'{key}' takes no weights — cannot serve it"));
        }
        if weights.len() != spec.inputs.len() - 1 {
            return Err(err!(
                "artifact '{key}' wants {} weight tensors, got {}",
                spec.inputs.len() - 1,
                weights.len()
            ));
        }
        let in_dims = {
            let d = &spec.inputs[0];
            [d[0], d[1], d[2], d[3]]
        };
        for (i, w) in weights.iter().enumerate() {
            let want = &spec.inputs[i + 1];
            if w.dims.to_vec() != *want {
                return Err(err!(
                    "weights[{i}] shape {:?} != artifact filter {:?}",
                    w.dims,
                    want
                ));
            }
        }
        if let Some(pol) = opts.queue {
            if pol.capacity == 0 {
                return Err(err!("queue capacity must be >= 1"));
            }
        }
        // weights live behind Arcs for the whole executor lifetime: each
        // batch reuses them with zero copies
        let weights: Vec<Arc<Tensor4>> =
            weights.into_iter().map(Arc::new).collect();
        let key = key.to_string();
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let batch = in_dims[0];
        let out_dims = [spec.output[0], spec.output[1], spec.output[2], spec.output[3]];
        let linger = opts.linger;
        let next_id = Arc::new(AtomicU64::new(0));
        let queue_depth = Arc::new(AtomicU64::new(0));
        let peak_depth = Arc::new(AtomicU64::new(0));
        let shed = Arc::new(AtomicU64::new(0));
        let closed = Arc::new(AtomicBool::new(false));
        let gate = Arc::new((Mutex::new(()), Condvar::new()));
        let (submitted, depth, peak, shed_n, closed_x, gate_x) = (
            Arc::clone(&next_id),
            Arc::clone(&queue_depth),
            Arc::clone(&peak_depth),
            Arc::clone(&shed),
            Arc::clone(&closed),
            Arc::clone(&gate),
        );
        let exec_trace = trace.clone();

        let handle = thread::Builder::new()
            .name("convbound-executor".into())
            .spawn(move || -> Result<ServerStats> {
                let trace = exec_trace;
                let _closer = ClosedOnExit { closed: closed_x, gate: Arc::clone(&gate_x) };
                // one pull off the channel: depth bookkeeping + waking a
                // Block-mode submitter waiting for the freed slot
                let pulled = |_: &Job| {
                    depth.fetch_sub(1, Ordering::SeqCst);
                    let (lock, cv) = &*gate_x;
                    let _g = lock.lock().unwrap_or_else(PoisonError::into_inner);
                    cv.notify_all();
                };
                let rt = (|| -> Result<Runtime> {
                    let mut rt = source.runtime()?;
                    rt.load(&key)?;
                    Ok(rt)
                })();
                let rt = match rt {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e.clone()));
                        return Err(e);
                    }
                };
                let mut stats = ServerStats::default();
                let mut latencies: Vec<f64> = Vec::new();
                let mut completed: u64 = 0;
                let mut failed: u64 = 0;
                let mut expired: u64 = 0;
                let mut server_panics: u64 = 0;
                let mut seq: u64 = 0;
                let mut queue: Vec<Job> = Vec::with_capacity(batch);
                // Set when a Stop arrives inside the linger window: the
                // in-flight batch must still be flushed, then the executor
                // exits. (A Stop that only broke batch assembly would leave
                // the loop re-blocking on recv() while shutdown() joins with
                // the sender still alive — a deadlock.)
                let mut stopping = false;
                'serve: while !stopping {
                    // block for the first *live* job: expired jobs are shed
                    // here, before they could claim a batch slot
                    let first = loop {
                        match rx.recv() {
                            Ok(Msg::Run(j)) => {
                                pulled(&j);
                                if job_expired(&j) {
                                    expired += 1;
                                    reject_job(
                                        &trace,
                                        j,
                                        "expired",
                                        &Error::typed(
                                            ErrorKind::DeadlineExceeded,
                                            "deadline exceeded before batching",
                                        ),
                                    );
                                    continue;
                                }
                                break j;
                            }
                            Ok(Msg::Stop) | Err(_) => break 'serve,
                        }
                    };
                    queue.push(first);
                    let linger_until = Instant::now() + linger;
                    while queue.len() < batch {
                        let left = linger_until.saturating_duration_since(Instant::now());
                        match rx.recv_timeout(left) {
                            Ok(Msg::Run(j)) => {
                                pulled(&j);
                                if job_expired(&j) {
                                    expired += 1;
                                    reject_job(
                                        &trace,
                                        j,
                                        "expired",
                                        &Error::typed(
                                            ErrorKind::DeadlineExceeded,
                                            "deadline exceeded before batching",
                                        ),
                                    );
                                    continue;
                                }
                                queue.push(j);
                            }
                            Ok(Msg::Stop) => {
                                stopping = true;
                                break;
                            }
                            Err(mpsc::RecvTimeoutError::Timeout) => break,
                            Err(mpsc::RecvTimeoutError::Disconnected) => {
                                stopping = true;
                                break;
                            }
                        }
                    }
                    let batch_scope = if trace.enabled() {
                        let reqs: Vec<Json> =
                            queue.iter().map(|j| Json::Num(j.id as f64)).collect();
                        Some(trace.scope(
                            obs::kind::BATCH,
                            &[
                                ("seq", ju(seq)),
                                ("key", js(&key)),
                                ("size", ju(queue.len() as u64)),
                                ("padded", ju((batch - queue.len()) as u64)),
                                ("linger_flush", jb(queue.len() < batch)),
                                ("reqs", Json::Arr(reqs)),
                            ],
                        ))
                    } else {
                        None
                    };
                    seq += 1;
                    // deterministic slow backend for the fault harness's
                    // backpressure/deadline tests
                    if faults::armed() {
                        faults::queue_point();
                    }
                    // assemble the batch (zero-padding the tail); the
                    // batch tensor and the shared weights reach the
                    // backend as Arcs — no further copies on the way to
                    // the worker pool
                    let mut x = Tensor4::zeros(in_dims);
                    let img_len = in_dims[1] * in_dims[2] * in_dims[3];
                    for (slot, job) in queue.iter().enumerate() {
                        x.data[slot * img_len..(slot + 1) * img_len]
                            .copy_from_slice(&job.image.data);
                    }
                    let mut operands: Vec<Arc<Tensor4>> =
                        Vec::with_capacity(1 + weights.len());
                    operands.push(Arc::new(x));
                    operands.extend(weights.iter().cloned());
                    let dispatch_scope = if trace.enabled() {
                        Some(trace.scope(obs::kind::DISPATCH, &[("key", js(&key))]))
                    } else {
                        None
                    };
                    let t0 = Instant::now();
                    let out = match dispatch_once(&rt, &key, &operands, &trace, &mut server_panics)
                    {
                        Ok(v) => Ok(v),
                        Err(first_err) => {
                            // a batch dispatch is idempotent (pure function
                            // of the operands): retry once with a short
                            // backoff before failing the batch's requests
                            thread::sleep(Duration::from_millis(2));
                            dispatch_once(&rt, &key, &operands, &trace, &mut server_panics)
                                .map_err(|e| {
                                    e.context(format!(
                                        "after retry (first attempt: {first_err})"
                                    ))
                                })
                        }
                    };
                    let exec_secs = t0.elapsed().as_secs_f64();
                    if let Some(g) = dispatch_scope {
                        g.end(&[("secs", jf(exec_secs)), ("ok", jb(out.is_ok()))]);
                    }
                    stats.total_exec_secs += exec_secs;
                    stats.batches += 1;
                    stats.padded_slots += (batch - queue.len()) as u64;
                    match out {
                        Ok(out) => {
                            stats.requests += queue.len() as u64;
                            // split and reply
                            let out_len = out_dims[1] * out_dims[2] * out_dims[3];
                            for (slot, job) in queue.drain(..).enumerate() {
                                let mut o = Tensor4::zeros([
                                    1, out_dims[1], out_dims[2], out_dims[3],
                                ]);
                                o.data.copy_from_slice(
                                    &out.data[slot * out_len..(slot + 1) * out_len],
                                );
                                let latency = job.enqueued.elapsed();
                                latencies.push(latency.as_secs_f64());
                                completed += 1;
                                trace.span_close(
                                    obs::kind::REQUEST,
                                    job.span,
                                    &[
                                        ("req", ju(job.id)),
                                        ("disposition", js("ok")),
                                        ("latency_secs", jf(latency.as_secs_f64())),
                                    ],
                                );
                                let _ = job.reply.send(Ok(ConvResponse {
                                    id: job.id,
                                    output: o,
                                    latency,
                                }));
                            }
                        }
                        Err(e) => {
                            // fail only this batch's requests; the
                            // executor, pool and server all stay up
                            let e = e.context(format!("dispatching batch {}", seq - 1));
                            for job in queue.drain(..) {
                                failed += 1;
                                reject_job(&trace, job, "failed", &e);
                            }
                        }
                    }
                    if let Some(g) = batch_scope {
                        g.end(&[("exec_secs", jf(exec_secs))]);
                    }
                }
                // drain requests that never ran (sent before Stop but
                // still in the channel): fail them with a typed error, and
                // the accounting below must still balance
                while let Ok(msg) = rx.try_recv() {
                    if let Msg::Run(job) = msg {
                        pulled(&job);
                        failed += 1;
                        reject_job(
                            &trace,
                            job,
                            "failed",
                            &Error::typed(
                                ErrorKind::Shutdown,
                                "server stopped before execution",
                            ),
                        );
                    }
                }
                stats.failed = failed;
                stats.expired = expired;
                stats.shed = shed_n.load(Ordering::SeqCst);
                let fault = rt.fault_stats();
                stats.panicked = fault.panicked + server_panics;
                stats.degraded = fault.degraded;
                stats.peak_queue_depth = peak.load(Ordering::Relaxed);
                latencies.sort_by(f64::total_cmp);
                if !latencies.is_empty() {
                    stats.latency_p50_ms = percentile(&latencies, 0.50) * 1e3;
                    stats.latency_p95_ms = percentile(&latencies, 0.95) * 1e3;
                    stats.latency_p99_ms = percentile(&latencies, 0.99) * 1e3;
                }
                // the books must balance: every submitted request ended in
                // exactly one disposition — replied (ok), failed, expired,
                // or shed at admission
                let submitted_total = submitted.load(Ordering::SeqCst);
                assert_eq!(
                    completed + failed + expired + stats.shed,
                    submitted_total,
                    "server accounting: ok + failed + expired + shed != submitted"
                );
                assert_eq!(completed, stats.requests, "server accounting");
                if trace.enabled() {
                    trace.event(
                        obs::kind::SERVER_STATS,
                        &[
                            ("key", js(&key)),
                            ("requests", ju(stats.requests)),
                            ("failed", ju(stats.failed)),
                            ("shed", ju(stats.shed)),
                            ("expired", ju(stats.expired)),
                            ("panicked", ju(stats.panicked)),
                            ("degraded", ju(stats.degraded)),
                            ("batches", ju(stats.batches)),
                            ("padded_slots", ju(stats.padded_slots)),
                            ("exec_secs", jf(stats.total_exec_secs)),
                            ("latency_p50_ms", jf(stats.latency_p50_ms)),
                            ("latency_p95_ms", jf(stats.latency_p95_ms)),
                            ("latency_p99_ms", jf(stats.latency_p99_ms)),
                            ("peak_queue_depth", ju(stats.peak_queue_depth)),
                        ],
                    );
                    trace.flush();
                }
                Ok(stats)
            })
            .expect("spawn executor");

        // surface compile/load failures synchronously
        ready_rx
            .recv()
            .map_err(|_| err!("executor died during startup"))??;

        Ok(ConvServer {
            tx,
            handle: Some(handle),
            next_id,
            queue_depth,
            peak_depth,
            shed,
            closed,
            gate,
            policy: opts.queue,
            deadline: opts.deadline,
            trace,
            batch,
            in_dims,
        })
    }

    /// The artifact's compiled batch size.
    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Admission control: claim a queue slot under the configured policy.
    /// Returns the depth *after* this enqueue.
    fn admit(&self) -> Result<u64> {
        let Some(pol) = self.policy else {
            // unbounded legacy path
            return Ok(self.queue_depth.fetch_add(1, Ordering::SeqCst) + 1);
        };
        match pol.overflow {
            Overflow::Shed => {
                // CAS loop: concurrent submitters can never push the depth
                // past capacity, so peak_queue_depth <= capacity holds
                // strictly
                let mut cur = self.queue_depth.load(Ordering::SeqCst);
                loop {
                    if cur >= pol.capacity {
                        return Err(Error::typed(
                            ErrorKind::QueueFull,
                            format!("queue full ({} requests)", pol.capacity),
                        ));
                    }
                    match self.queue_depth.compare_exchange(
                        cur,
                        cur + 1,
                        Ordering::SeqCst,
                        Ordering::SeqCst,
                    ) {
                        Ok(_) => return Ok(cur + 1),
                        Err(now) => cur = now,
                    }
                }
            }
            Overflow::Block => {
                let (lock, cv) = &*self.gate;
                let mut g = lock.lock().unwrap_or_else(PoisonError::into_inner);
                loop {
                    if self.closed.load(Ordering::SeqCst) {
                        return Err(Error::typed(ErrorKind::Shutdown, "server stopped"));
                    }
                    let cur = self.queue_depth.load(Ordering::SeqCst);
                    if cur < pol.capacity {
                        self.queue_depth.fetch_add(1, Ordering::SeqCst);
                        return Ok(cur + 1);
                    }
                    // bounded wait + re-check: immune to lost wakeups and
                    // to an executor that dies without notifying
                    let (g2, _) = cv
                        .wait_timeout(g, Duration::from_millis(5))
                        .unwrap_or_else(PoisonError::into_inner);
                    g = g2;
                }
            }
        }
    }

    /// Submit one image (shape (1, cI, WI, HI)); returns the response
    /// channel immediately (the response itself is a `Result`: a batch
    /// whose dispatch failed, or a request past its deadline, answers
    /// with a typed error). Accepts an owned [`Tensor4`] or an
    /// `Arc<Tensor4>` — either way the image crosses into the executor
    /// without being cloned.
    ///
    /// Typed failure modes: `QueueFull` (bounded `Shed` queue at
    /// capacity) and `Shutdown` (server stopped) — never a panic.
    pub fn submit(
        &self,
        image: impl Into<Arc<Tensor4>>,
    ) -> Result<mpsc::Receiver<Result<ConvResponse>>> {
        let image: Arc<Tensor4> = image.into();
        let want = [1, self.in_dims[1], self.in_dims[2], self.in_dims[3]];
        if image.dims != want {
            return Err(err!("image shape {:?} != {:?}", image.dims, want));
        }
        let depth = match self.admit() {
            Ok(d) => d,
            Err(e) => {
                if e.kind() == ErrorKind::QueueFull {
                    // a shed request still gets an id and a complete
                    // request span, so the accounting identity and the
                    // trace replay both see it
                    let id = self.next_id.fetch_add(1, Ordering::SeqCst);
                    self.shed.fetch_add(1, Ordering::SeqCst);
                    let span = self.trace.span_id();
                    self.trace.span_open(
                        obs::kind::REQUEST,
                        span,
                        None,
                        &[("req", ju(id)), ("queue_depth", ju(self.queue_depth.load(Ordering::SeqCst)))],
                    );
                    self.trace.span_close(
                        obs::kind::REQUEST,
                        span,
                        &[
                            ("req", ju(id)),
                            ("disposition", js("shed")),
                            ("cause", js(&e.to_string())),
                        ],
                    );
                }
                return Err(e);
            }
        };
        self.peak_depth.fetch_max(depth, Ordering::SeqCst);
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let span = self.trace.span_id();
        self.trace.span_open(
            obs::kind::REQUEST,
            span,
            None,
            &[("req", ju(id)), ("queue_depth", ju(depth))],
        );
        let now = Instant::now();
        let deadline = self.deadline.map(|d| now + d);
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Run(Job { id, span, image, enqueued: now, deadline, reply }))
            .map_err(|_| {
                // the executor is gone: undo the books for this request
                // and close its span so a captured trace still balances
                self.queue_depth.fetch_sub(1, Ordering::SeqCst);
                self.trace.span_close(
                    obs::kind::REQUEST,
                    span,
                    &[
                        ("req", ju(id)),
                        ("disposition", js("failed")),
                        ("cause", js("server stopped")),
                    ],
                );
                Error::typed(ErrorKind::Shutdown, "server stopped")
            })?;
        Ok(rx)
    }

    /// Wake every blocked submitter with the server marked closed.
    fn close_gate(&self) {
        self.closed.store(true, Ordering::SeqCst);
        let (lock, cv) = &*self.gate;
        let _g = lock.lock().unwrap_or_else(PoisonError::into_inner);
        cv.notify_all();
    }

    /// Stop the executor and collect final statistics. Returns promptly
    /// even when the Stop lands inside the linger window: the executor
    /// flushes the in-flight batch and exits.
    pub fn shutdown(mut self) -> Result<ServerStats> {
        self.close_gate();
        let _ = self.tx.send(Msg::Stop);
        let handle = self.handle.take().expect("not yet joined");
        handle.join().map_err(|_| err!("executor panicked"))?
    }
}

impl Drop for ConvServer {
    fn drop(&mut self) {
        self.close_gate();
        let _ = self.tx.send(Msg::Stop);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    // End-to-end server tests (including the shutdown-under-load and
    // dropped-client regressions) live in rust/tests/coordinator_e2e.rs;
    // the fault-injection suite lives in rust/tests/faults_e2e.rs. This
    // module keeps only the teardown regression that needs the private
    // channel.
    use super::*;

    fn builtin_server() -> ConvServer {
        let m = Manifest::builtin(crate::runtime::manifest::BUILTIN_BATCH);
        let spec = m.find("unit3x3/blocked").expect("builtin key").clone();
        let wd = &spec.inputs[1];
        let w = Tensor4::randn([wd[0], wd[1], wd[2], wd[3]], 1);
        ConvServer::start_builtin("unit3x3/blocked", w, Duration::from_millis(1))
            .expect("server starts")
    }

    #[test]
    fn submit_after_executor_stop_returns_typed_shutdown_error() {
        let server = builtin_server();
        // stop the executor out-of-band (shutdown() would consume the
        // handle); submits racing the stop must fail typed, never panic
        server.tx.send(Msg::Stop).expect("executor alive");
        let deadline = Instant::now() + Duration::from_secs(10);
        // the executor flips `closed` on exit — wait for it so the
        // accounting assert inside the executor has already run
        while !server.closed.load(Ordering::SeqCst) {
            assert!(Instant::now() < deadline, "executor never exited");
            thread::sleep(Duration::from_millis(1));
        }
        let d = server.in_dims;
        loop {
            let img = Tensor4::randn([1, d[1], d[2], d[3]], 2);
            match server.submit(img) {
                Err(e) => {
                    assert_eq!(e.kind(), ErrorKind::Shutdown);
                    assert!(e.to_string().contains("server stopped"), "got: {e}");
                    break;
                }
                // the channel closes when the executor's receiver drops,
                // an instant after `closed` flips; retry until then
                Ok(_) => {
                    assert!(Instant::now() < deadline, "submit kept succeeding");
                    thread::sleep(Duration::from_millis(1));
                }
            }
        }
        drop(server); // Drop joins the already-exited executor cleanly
    }
}
