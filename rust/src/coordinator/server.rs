//! Batched convolution serving over the execution runtime.
//!
//! Architecture (single executor thread — backend handles are not
//! guaranteed `Send` (PJRT's are not), so the runtime lives on its own
//! thread and requests flow through channels):
//!
//! ```text
//! clients ── submit(image) ──► queue ──► batcher (size N, timeout) ──► backend
//!     ◄── per-request channel ◄── splitter ◄── output batch ◄────────────┘
//! ```
//!
//! Short batches (queue drained before N images arrived) are zero-padded;
//! padded slots are tracked in [`ServerStats`] since they waste MACs — the
//! batcher exists precisely to amortize the artifact's fixed batch size.
//!
//! With the default native backend a server needs no artifacts at all:
//! [`ConvServer::start_builtin`] serves the synthetic
//! [`Manifest::builtin`] layers end to end,
//! [`ConvServer::start_builtin_network`] serves whole-network requests
//! through the fused pipeline (one filter tensor per stage, one submit per
//! image, the response is the final stage's activation slice), and
//! [`ConvServer::start_builtin_training`] serves the same pipeline's fused
//! *backward* sweep (`"training"` artifacts: submit a tail loss-gradient
//! slice, receive the head image-gradient slice) — the batcher, padding
//! accounting and zero-copy path are identical because a training artifact
//! has the same shape contract: one batched request operand plus fixed
//! per-stage weights.
//!
//! Zero-copy path: [`ConvServer::submit`] takes anything convertible into
//! an `Arc<Tensor4>`, weights are held in `Arc`s for the lifetime of the
//! executor, and each assembled batch reaches the backend through
//! [`Runtime::run_arc`] — the native `"tiled"`/`"network"` dispatch hands
//! those `Arc`s straight to its worker pool instead of cloning request
//! tensors per batch.

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use crate::conv::Tensor4;
use crate::err;
use crate::obs::{self, jb, jf, js, ju, SpanId, TraceSink};
use crate::runtime::{Manifest, Runtime};
use crate::util::error::Result;
use crate::util::json::Json;
use crate::util::stats::percentile;

/// A finished request.
#[derive(Debug)]
pub struct ConvResponse {
    pub id: u64,
    /// (1, cO, wO, hO) slice of the batch output
    pub output: Tensor4,
    /// submit → response time
    pub latency: Duration,
}

struct Job {
    id: u64,
    /// trace span opened at enqueue (0 when tracing is off)
    span: SpanId,
    image: Arc<Tensor4>,
    enqueued: Instant,
    reply: mpsc::Sender<ConvResponse>,
}

enum Msg {
    Run(Job),
    Stop,
}

/// Aggregate serving statistics, plus per-request latency percentiles
/// and the peak batching-queue depth — both computed from the samples
/// the executor records (via [`crate::util::stats::percentile`]), not
/// estimated.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ServerStats {
    /// Requests executed and replied to.
    pub requests: u64,
    /// Requests accepted but never executed (still queued at shutdown).
    pub failed: u64,
    pub batches: u64,
    pub padded_slots: u64,
    pub total_exec_secs: f64,
    pub latency_p50_ms: f64,
    pub latency_p95_ms: f64,
    pub latency_p99_ms: f64,
    /// Max submitted-but-not-yet-drained requests observed at any enqueue.
    pub peak_queue_depth: u64,
}

/// Where the executor thread gets its runtime. Backend handles may not be
/// `Send`, so only this description crosses into the thread; the runtime is
/// constructed there.
enum Source {
    Dir(PathBuf),
    Builtin,
}

impl Source {
    fn manifest(&self) -> Result<Manifest> {
        match self {
            Source::Dir(d) => Manifest::load(d.join("manifest.json")),
            // the same constant Runtime::builtin uses, so the shapes
            // validated here are exactly the shapes the executor runs
            Source::Builtin => {
                Ok(Manifest::builtin(crate::runtime::manifest::BUILTIN_BATCH))
            }
        }
    }

    fn runtime(&self) -> Result<Runtime> {
        match self {
            Source::Dir(d) => Runtime::new(d),
            Source::Builtin => Ok(Runtime::builtin()),
        }
    }
}

/// Handle to the executor thread.
pub struct ConvServer {
    tx: mpsc::Sender<Msg>,
    handle: Option<thread::JoinHandle<Result<ServerStats>>>,
    /// shared with the executor: total requests accepted (the shutdown
    /// path asserts completed + failed == this)
    next_id: Arc<AtomicU64>,
    /// submitted-but-not-yet-drained requests (incremented at submit,
    /// decremented when the executor pulls the job off the channel)
    queue_depth: Arc<AtomicU64>,
    /// max queue depth ever observed at an enqueue
    peak_depth: Arc<AtomicU64>,
    trace: TraceSink,
    batch: usize,
    in_dims: [usize; 4],
}

impl ConvServer {
    /// Start a server for one single-layer artifact `key` from an artifact
    /// directory, with fixed filter weights. `linger` bounds how long the
    /// batcher waits to fill a batch once it holds at least one request.
    pub fn start(
        artifact_dir: impl AsRef<Path>,
        key: &str,
        weights: Tensor4,
        linger: Duration,
    ) -> Result<ConvServer> {
        ConvServer::start_source(
            Source::Dir(artifact_dir.as_ref().to_path_buf()),
            key,
            vec![weights],
            linger,
            TraceSink::global(),
        )
    }

    /// Start a server over the built-in native manifest — no artifact
    /// directory required (keys: `unit3x3/blocked`, `unit3x3/im2col`,
    /// `unit1x1/blocked`, `unit5x5/blocked`).
    pub fn start_builtin(
        key: &str,
        weights: Tensor4,
        linger: Duration,
    ) -> Result<ConvServer> {
        ConvServer::start_source(
            Source::Builtin,
            key,
            vec![weights],
            linger,
            TraceSink::global(),
        )
    }

    /// Start a built-in server with an explicit [`TraceSink`] instead of
    /// the process-global one — the wiring tests and embedders use to
    /// capture exactly one server's events. Takes one weight tensor per
    /// artifact filter input, so it serves single-layer, network and
    /// training keys alike.
    pub fn start_builtin_traced(
        key: &str,
        weights: Vec<Tensor4>,
        linger: Duration,
        trace: TraceSink,
    ) -> Result<ConvServer> {
        ConvServer::start_source(Source::Builtin, key, weights, linger, trace)
    }

    /// Start a server for a whole-network artifact from a directory: one
    /// fixed filter tensor per stage, requests batched exactly like the
    /// single-layer path, responses carrying the final stage's activation.
    pub fn start_network(
        artifact_dir: impl AsRef<Path>,
        key: &str,
        weights: Vec<Tensor4>,
        linger: Duration,
    ) -> Result<ConvServer> {
        ConvServer::start_source(
            Source::Dir(artifact_dir.as_ref().to_path_buf()),
            key,
            weights,
            linger,
            TraceSink::global(),
        )
    }

    /// Start a whole-network server over the built-in native manifest
    /// (key: `tiny_resnet/network`, one filter per stage).
    pub fn start_builtin_network(
        key: &str,
        weights: Vec<Tensor4>,
        linger: Duration,
    ) -> Result<ConvServer> {
        ConvServer::start_source(
            Source::Builtin,
            key,
            weights,
            linger,
            TraceSink::global(),
        )
    }

    /// Start a gradient server over the built-in native manifest (key:
    /// `tiny_resnet/training`, one fixed filter per stage). Requests are
    /// tail loss-gradient slices `(1, cO, wO, hO)`; each response is the
    /// head image-gradient slice the fused backward sweep produces —
    /// bitwise identical to chaining the per-stage dInput oracles.
    pub fn start_builtin_training(
        key: &str,
        weights: Vec<Tensor4>,
        linger: Duration,
    ) -> Result<ConvServer> {
        ConvServer::start_source(
            Source::Builtin,
            key,
            weights,
            linger,
            TraceSink::global(),
        )
    }

    fn start_source(
        source: Source,
        key: &str,
        weights: Vec<Tensor4>,
        linger: Duration,
        trace: TraceSink,
    ) -> Result<ConvServer> {
        // Validate shapes from the manifest up front (plain data,
        // Send-safe); the runtime itself is created *inside* the executor
        // thread — its backend handles may not be Send.
        let manifest = source.manifest()?;
        let spec = manifest
            .find(key)
            .ok_or_else(|| err!("artifact '{key}' not found"))?
            .clone();
        if spec.inputs.len() < 2 {
            return Err(err!("'{key}' takes no weights — cannot serve it"));
        }
        if weights.len() != spec.inputs.len() - 1 {
            return Err(err!(
                "artifact '{key}' wants {} weight tensors, got {}",
                spec.inputs.len() - 1,
                weights.len()
            ));
        }
        let in_dims = {
            let d = &spec.inputs[0];
            [d[0], d[1], d[2], d[3]]
        };
        for (i, w) in weights.iter().enumerate() {
            let want = &spec.inputs[i + 1];
            if w.dims.to_vec() != *want {
                return Err(err!(
                    "weights[{i}] shape {:?} != artifact filter {:?}",
                    w.dims,
                    want
                ));
            }
        }
        // weights live behind Arcs for the whole executor lifetime: each
        // batch reuses them with zero copies
        let weights: Vec<Arc<Tensor4>> =
            weights.into_iter().map(Arc::new).collect();
        let key = key.to_string();
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let batch = in_dims[0];
        let out_dims = [spec.output[0], spec.output[1], spec.output[2], spec.output[3]];
        let next_id = Arc::new(AtomicU64::new(0));
        let queue_depth = Arc::new(AtomicU64::new(0));
        let peak_depth = Arc::new(AtomicU64::new(0));
        let (submitted, depth, peak) =
            (Arc::clone(&next_id), Arc::clone(&queue_depth), Arc::clone(&peak_depth));
        let exec_trace = trace.clone();

        let handle = thread::Builder::new()
            .name("convbound-executor".into())
            .spawn(move || -> Result<ServerStats> {
                let trace = exec_trace;
                let rt = (|| -> Result<Runtime> {
                    let mut rt = source.runtime()?;
                    rt.load(&key)?;
                    Ok(rt)
                })();
                let rt = match rt {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e.clone()));
                        return Err(e);
                    }
                };
                let mut stats = ServerStats::default();
                let mut latencies: Vec<f64> = Vec::new();
                let mut completed: u64 = 0;
                let mut failed: u64 = 0;
                let mut seq: u64 = 0;
                let mut queue: Vec<Job> = Vec::with_capacity(batch);
                // Set when a Stop arrives inside the linger window: the
                // in-flight batch must still be flushed, then the executor
                // exits. (A Stop that only broke batch assembly would leave
                // the loop re-blocking on recv() while shutdown() joins with
                // the sender still alive — a deadlock.)
                let mut stopping = false;
                while !stopping {
                    // block for the first job, then linger for the rest
                    let first = match rx.recv() {
                        Ok(Msg::Run(j)) => j,
                        Ok(Msg::Stop) | Err(_) => break,
                    };
                    depth.fetch_sub(1, Ordering::Relaxed);
                    queue.push(first);
                    let deadline = Instant::now() + linger;
                    while queue.len() < batch {
                        let left = deadline.saturating_duration_since(Instant::now());
                        match rx.recv_timeout(left) {
                            Ok(Msg::Run(j)) => {
                                depth.fetch_sub(1, Ordering::Relaxed);
                                queue.push(j);
                            }
                            Ok(Msg::Stop) => {
                                stopping = true;
                                break;
                            }
                            Err(mpsc::RecvTimeoutError::Timeout) => break,
                            Err(mpsc::RecvTimeoutError::Disconnected) => {
                                stopping = true;
                                break;
                            }
                        }
                    }
                    let batch_scope = if trace.enabled() {
                        let reqs: Vec<Json> =
                            queue.iter().map(|j| Json::Num(j.id as f64)).collect();
                        Some(trace.scope(
                            obs::kind::BATCH,
                            &[
                                ("seq", ju(seq)),
                                ("key", js(&key)),
                                ("size", ju(queue.len() as u64)),
                                ("padded", ju((batch - queue.len()) as u64)),
                                ("linger_flush", jb(queue.len() < batch)),
                                ("reqs", Json::Arr(reqs)),
                            ],
                        ))
                    } else {
                        None
                    };
                    seq += 1;
                    // assemble the batch (zero-padding the tail); the
                    // batch tensor and the shared weights reach the
                    // backend as Arcs — no further copies on the way to
                    // the worker pool
                    let mut x = Tensor4::zeros(in_dims);
                    let img_len = in_dims[1] * in_dims[2] * in_dims[3];
                    for (slot, job) in queue.iter().enumerate() {
                        x.data[slot * img_len..(slot + 1) * img_len]
                            .copy_from_slice(&job.image.data);
                    }
                    let mut operands: Vec<Arc<Tensor4>> =
                        Vec::with_capacity(1 + weights.len());
                    operands.push(Arc::new(x));
                    operands.extend(weights.iter().cloned());
                    let dispatch_scope = if trace.enabled() {
                        Some(trace.scope(obs::kind::DISPATCH, &[("key", js(&key))]))
                    } else {
                        None
                    };
                    let t0 = Instant::now();
                    let out = rt.run_arc(&key, &operands)?;
                    let exec_secs = t0.elapsed().as_secs_f64();
                    if let Some(g) = dispatch_scope {
                        g.end(&[("secs", jf(exec_secs))]);
                    }
                    stats.total_exec_secs += exec_secs;
                    stats.batches += 1;
                    stats.requests += queue.len() as u64;
                    stats.padded_slots += (batch - queue.len()) as u64;
                    // split and reply
                    let out_len = out_dims[1] * out_dims[2] * out_dims[3];
                    for (slot, job) in queue.drain(..).enumerate() {
                        let mut o =
                            Tensor4::zeros([1, out_dims[1], out_dims[2], out_dims[3]]);
                        o.data.copy_from_slice(
                            &out.data[slot * out_len..(slot + 1) * out_len],
                        );
                        let latency = job.enqueued.elapsed();
                        latencies.push(latency.as_secs_f64());
                        completed += 1;
                        trace.span_close(
                            obs::kind::REQUEST,
                            job.span,
                            &[
                                ("req", ju(job.id)),
                                ("latency_secs", jf(latency.as_secs_f64())),
                            ],
                        );
                        let _ = job.reply.send(ConvResponse {
                            id: job.id,
                            output: o,
                            latency,
                        });
                    }
                    if let Some(g) = batch_scope {
                        g.end(&[("exec_secs", jf(exec_secs))]);
                    }
                }
                // drain requests that never ran (sent before Stop but
                // still in the channel): their reply channels drop, and
                // the accounting below must still balance
                while let Ok(msg) = rx.try_recv() {
                    if let Msg::Run(job) = msg {
                        depth.fetch_sub(1, Ordering::Relaxed);
                        failed += 1;
                        trace.span_close(
                            obs::kind::REQUEST,
                            job.span,
                            &[("req", ju(job.id)), ("dropped", jb(true))],
                        );
                    }
                }
                stats.failed = failed;
                stats.peak_queue_depth = peak.load(Ordering::Relaxed);
                latencies.sort_by(f64::total_cmp);
                if !latencies.is_empty() {
                    stats.latency_p50_ms = percentile(&latencies, 0.50) * 1e3;
                    stats.latency_p95_ms = percentile(&latencies, 0.95) * 1e3;
                    stats.latency_p99_ms = percentile(&latencies, 0.99) * 1e3;
                }
                // the books must balance: every accepted request either
                // got a reply or was drained above
                let submitted_total = submitted.load(Ordering::SeqCst);
                assert_eq!(
                    completed + failed,
                    submitted_total,
                    "server accounting: completed + failed != submitted"
                );
                assert_eq!(completed, stats.requests, "server accounting");
                if trace.enabled() {
                    trace.event(
                        obs::kind::SERVER_STATS,
                        &[
                            ("key", js(&key)),
                            ("requests", ju(stats.requests)),
                            ("failed", ju(stats.failed)),
                            ("batches", ju(stats.batches)),
                            ("padded_slots", ju(stats.padded_slots)),
                            ("exec_secs", jf(stats.total_exec_secs)),
                            ("latency_p50_ms", jf(stats.latency_p50_ms)),
                            ("latency_p95_ms", jf(stats.latency_p95_ms)),
                            ("latency_p99_ms", jf(stats.latency_p99_ms)),
                            ("peak_queue_depth", ju(stats.peak_queue_depth)),
                        ],
                    );
                    trace.flush();
                }
                Ok(stats)
            })
            .expect("spawn executor");

        // surface compile/load failures synchronously
        ready_rx
            .recv()
            .map_err(|_| err!("executor died during startup"))??;

        Ok(ConvServer {
            tx,
            handle: Some(handle),
            next_id,
            queue_depth,
            peak_depth,
            trace,
            batch,
            in_dims,
        })
    }

    /// The artifact's compiled batch size.
    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Submit one image (shape (1, cI, WI, HI)); returns the response
    /// channel immediately. Accepts an owned [`Tensor4`] or an
    /// `Arc<Tensor4>` — either way the image crosses into the executor
    /// without being cloned.
    pub fn submit(
        &self,
        image: impl Into<Arc<Tensor4>>,
    ) -> Result<mpsc::Receiver<ConvResponse>> {
        let image: Arc<Tensor4> = image.into();
        let want = [1, self.in_dims[1], self.in_dims[2], self.in_dims[3]];
        if image.dims != want {
            return Err(err!("image shape {:?} != {:?}", image.dims, want));
        }
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let depth = self.queue_depth.fetch_add(1, Ordering::Relaxed) + 1;
        self.peak_depth.fetch_max(depth, Ordering::Relaxed);
        let span = self.trace.span_id();
        self.trace.span_open(
            obs::kind::REQUEST,
            span,
            None,
            &[("req", ju(id)), ("queue_depth", ju(depth))],
        );
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Run(Job { id, span, image, enqueued: Instant::now(), reply }))
            .map_err(|_| {
                // the executor is gone: undo the books for this request
                // and close its span so a captured trace still balances
                self.queue_depth.fetch_sub(1, Ordering::Relaxed);
                self.trace.span_close(
                    obs::kind::REQUEST,
                    span,
                    &[("req", ju(id)), ("dropped", jb(true))],
                );
                err!("server stopped")
            })?;
        Ok(rx)
    }

    /// Stop the executor and collect final statistics. Returns promptly
    /// even when the Stop lands inside the linger window: the executor
    /// flushes the in-flight batch and exits.
    pub fn shutdown(mut self) -> Result<ServerStats> {
        let _ = self.tx.send(Msg::Stop);
        let handle = self.handle.take().expect("not yet joined");
        handle.join().map_err(|_| err!("executor panicked"))?
    }
}

impl Drop for ConvServer {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Stop);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    // End-to-end server tests (including the shutdown-under-load
    // regression) live in rust/tests/coordinator_e2e.rs; they run on the
    // built-in native backend, no artifacts required.
}
