//! Batched convolution serving over the execution runtime.
//!
//! Architecture (single executor thread — backend handles are not
//! guaranteed `Send` (PJRT's are not), so the runtime lives on its own
//! thread and requests flow through channels):
//!
//! ```text
//! clients ── submit(image) ──► queue ──► batcher (size N, timeout) ──► backend
//!     ◄── per-request channel ◄── splitter ◄── output batch ◄────────────┘
//! ```
//!
//! Short batches (queue drained before N images arrived) are zero-padded;
//! padded slots are tracked in [`ServerStats`] since they waste MACs — the
//! batcher exists precisely to amortize the artifact's fixed batch size.
//!
//! With the default native backend a server needs no artifacts at all:
//! [`ConvServer::start_builtin`] serves the synthetic
//! [`Manifest::builtin`] layers end to end,
//! [`ConvServer::start_builtin_network`] serves whole-network requests
//! through the fused pipeline (one filter tensor per stage, one submit per
//! image, the response is the final stage's activation slice), and
//! [`ConvServer::start_builtin_training`] serves the same pipeline's fused
//! *backward* sweep (`"training"` artifacts: submit a tail loss-gradient
//! slice, receive the head image-gradient slice) — the batcher, padding
//! accounting and zero-copy path are identical because a training artifact
//! has the same shape contract: one batched request operand plus fixed
//! per-stage weights.
//!
//! Zero-copy path: [`ConvServer::submit`] takes anything convertible into
//! an `Arc<Tensor4>`, weights are held in `Arc`s for the lifetime of the
//! executor, and each assembled batch reaches the backend through
//! [`Runtime::run_arc`] — the native `"tiled"`/`"network"` dispatch hands
//! those `Arc`s straight to its worker pool instead of cloning request
//! tensors per batch.

use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use crate::conv::Tensor4;
use crate::err;
use crate::runtime::{Manifest, Runtime};
use crate::util::error::Result;

/// A finished request.
#[derive(Debug)]
pub struct ConvResponse {
    pub id: u64,
    /// (1, cO, wO, hO) slice of the batch output
    pub output: Tensor4,
    /// submit → response time
    pub latency: Duration,
}

struct Job {
    id: u64,
    image: Arc<Tensor4>,
    enqueued: Instant,
    reply: mpsc::Sender<ConvResponse>,
}

enum Msg {
    Run(Job),
    Stop,
}

/// Aggregate serving statistics.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ServerStats {
    pub requests: u64,
    pub batches: u64,
    pub padded_slots: u64,
    pub total_exec_secs: f64,
}

/// Where the executor thread gets its runtime. Backend handles may not be
/// `Send`, so only this description crosses into the thread; the runtime is
/// constructed there.
enum Source {
    Dir(PathBuf),
    Builtin,
}

impl Source {
    fn manifest(&self) -> Result<Manifest> {
        match self {
            Source::Dir(d) => Manifest::load(d.join("manifest.json")),
            // the same constant Runtime::builtin uses, so the shapes
            // validated here are exactly the shapes the executor runs
            Source::Builtin => {
                Ok(Manifest::builtin(crate::runtime::manifest::BUILTIN_BATCH))
            }
        }
    }

    fn runtime(&self) -> Result<Runtime> {
        match self {
            Source::Dir(d) => Runtime::new(d),
            Source::Builtin => Ok(Runtime::builtin()),
        }
    }
}

/// Handle to the executor thread.
pub struct ConvServer {
    tx: mpsc::Sender<Msg>,
    handle: Option<thread::JoinHandle<Result<ServerStats>>>,
    next_id: std::sync::atomic::AtomicU64,
    batch: usize,
    in_dims: [usize; 4],
}

impl ConvServer {
    /// Start a server for one single-layer artifact `key` from an artifact
    /// directory, with fixed filter weights. `linger` bounds how long the
    /// batcher waits to fill a batch once it holds at least one request.
    pub fn start(
        artifact_dir: impl AsRef<Path>,
        key: &str,
        weights: Tensor4,
        linger: Duration,
    ) -> Result<ConvServer> {
        ConvServer::start_source(
            Source::Dir(artifact_dir.as_ref().to_path_buf()),
            key,
            vec![weights],
            linger,
        )
    }

    /// Start a server over the built-in native manifest — no artifact
    /// directory required (keys: `unit3x3/blocked`, `unit3x3/im2col`,
    /// `unit1x1/blocked`, `unit5x5/blocked`).
    pub fn start_builtin(
        key: &str,
        weights: Tensor4,
        linger: Duration,
    ) -> Result<ConvServer> {
        ConvServer::start_source(Source::Builtin, key, vec![weights], linger)
    }

    /// Start a server for a whole-network artifact from a directory: one
    /// fixed filter tensor per stage, requests batched exactly like the
    /// single-layer path, responses carrying the final stage's activation.
    pub fn start_network(
        artifact_dir: impl AsRef<Path>,
        key: &str,
        weights: Vec<Tensor4>,
        linger: Duration,
    ) -> Result<ConvServer> {
        ConvServer::start_source(
            Source::Dir(artifact_dir.as_ref().to_path_buf()),
            key,
            weights,
            linger,
        )
    }

    /// Start a whole-network server over the built-in native manifest
    /// (key: `tiny_resnet/network`, one filter per stage).
    pub fn start_builtin_network(
        key: &str,
        weights: Vec<Tensor4>,
        linger: Duration,
    ) -> Result<ConvServer> {
        ConvServer::start_source(Source::Builtin, key, weights, linger)
    }

    /// Start a gradient server over the built-in native manifest (key:
    /// `tiny_resnet/training`, one fixed filter per stage). Requests are
    /// tail loss-gradient slices `(1, cO, wO, hO)`; each response is the
    /// head image-gradient slice the fused backward sweep produces —
    /// bitwise identical to chaining the per-stage dInput oracles.
    pub fn start_builtin_training(
        key: &str,
        weights: Vec<Tensor4>,
        linger: Duration,
    ) -> Result<ConvServer> {
        ConvServer::start_source(Source::Builtin, key, weights, linger)
    }

    fn start_source(
        source: Source,
        key: &str,
        weights: Vec<Tensor4>,
        linger: Duration,
    ) -> Result<ConvServer> {
        // Validate shapes from the manifest up front (plain data,
        // Send-safe); the runtime itself is created *inside* the executor
        // thread — its backend handles may not be Send.
        let manifest = source.manifest()?;
        let spec = manifest
            .find(key)
            .ok_or_else(|| err!("artifact '{key}' not found"))?
            .clone();
        if spec.inputs.len() < 2 {
            return Err(err!("'{key}' takes no weights — cannot serve it"));
        }
        if weights.len() != spec.inputs.len() - 1 {
            return Err(err!(
                "artifact '{key}' wants {} weight tensors, got {}",
                spec.inputs.len() - 1,
                weights.len()
            ));
        }
        let in_dims = {
            let d = &spec.inputs[0];
            [d[0], d[1], d[2], d[3]]
        };
        for (i, w) in weights.iter().enumerate() {
            let want = &spec.inputs[i + 1];
            if w.dims.to_vec() != *want {
                return Err(err!(
                    "weights[{i}] shape {:?} != artifact filter {:?}",
                    w.dims,
                    want
                ));
            }
        }
        // weights live behind Arcs for the whole executor lifetime: each
        // batch reuses them with zero copies
        let weights: Vec<Arc<Tensor4>> =
            weights.into_iter().map(Arc::new).collect();
        let key = key.to_string();
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let batch = in_dims[0];
        let out_dims = [spec.output[0], spec.output[1], spec.output[2], spec.output[3]];

        let handle = thread::Builder::new()
            .name("convbound-executor".into())
            .spawn(move || -> Result<ServerStats> {
                let rt = (|| -> Result<Runtime> {
                    let mut rt = source.runtime()?;
                    rt.load(&key)?;
                    Ok(rt)
                })();
                let rt = match rt {
                    Ok(rt) => {
                        let _ = ready_tx.send(Ok(()));
                        rt
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e.clone()));
                        return Err(e);
                    }
                };
                let mut stats = ServerStats::default();
                let mut queue: Vec<Job> = Vec::with_capacity(batch);
                // Set when a Stop arrives inside the linger window: the
                // in-flight batch must still be flushed, then the executor
                // exits. (A Stop that only broke batch assembly would leave
                // the loop re-blocking on recv() while shutdown() joins with
                // the sender still alive — a deadlock.)
                let mut stopping = false;
                while !stopping {
                    // block for the first job, then linger for the rest
                    let first = match rx.recv() {
                        Ok(Msg::Run(j)) => j,
                        Ok(Msg::Stop) | Err(_) => break,
                    };
                    queue.push(first);
                    let deadline = Instant::now() + linger;
                    while queue.len() < batch {
                        let left = deadline.saturating_duration_since(Instant::now());
                        match rx.recv_timeout(left) {
                            Ok(Msg::Run(j)) => queue.push(j),
                            Ok(Msg::Stop) => {
                                stopping = true;
                                break;
                            }
                            Err(mpsc::RecvTimeoutError::Timeout) => break,
                            Err(mpsc::RecvTimeoutError::Disconnected) => {
                                stopping = true;
                                break;
                            }
                        }
                    }
                    // assemble the batch (zero-padding the tail); the
                    // batch tensor and the shared weights reach the
                    // backend as Arcs — no further copies on the way to
                    // the worker pool
                    let mut x = Tensor4::zeros(in_dims);
                    let img_len = in_dims[1] * in_dims[2] * in_dims[3];
                    for (slot, job) in queue.iter().enumerate() {
                        x.data[slot * img_len..(slot + 1) * img_len]
                            .copy_from_slice(&job.image.data);
                    }
                    let mut operands: Vec<Arc<Tensor4>> =
                        Vec::with_capacity(1 + weights.len());
                    operands.push(Arc::new(x));
                    operands.extend(weights.iter().cloned());
                    let t0 = Instant::now();
                    let out = rt.run_arc(&key, &operands)?;
                    stats.total_exec_secs += t0.elapsed().as_secs_f64();
                    stats.batches += 1;
                    stats.requests += queue.len() as u64;
                    stats.padded_slots += (batch - queue.len()) as u64;
                    // split and reply
                    let out_len = out_dims[1] * out_dims[2] * out_dims[3];
                    for (slot, job) in queue.drain(..).enumerate() {
                        let mut o =
                            Tensor4::zeros([1, out_dims[1], out_dims[2], out_dims[3]]);
                        o.data.copy_from_slice(
                            &out.data[slot * out_len..(slot + 1) * out_len],
                        );
                        let _ = job.reply.send(ConvResponse {
                            id: job.id,
                            output: o,
                            latency: job.enqueued.elapsed(),
                        });
                    }
                }
                Ok(stats)
            })
            .expect("spawn executor");

        // surface compile/load failures synchronously
        ready_rx
            .recv()
            .map_err(|_| err!("executor died during startup"))??;

        Ok(ConvServer {
            tx,
            handle: Some(handle),
            next_id: std::sync::atomic::AtomicU64::new(0),
            batch,
            in_dims,
        })
    }

    /// The artifact's compiled batch size.
    pub fn batch_size(&self) -> usize {
        self.batch
    }

    /// Submit one image (shape (1, cI, WI, HI)); returns the response
    /// channel immediately. Accepts an owned [`Tensor4`] or an
    /// `Arc<Tensor4>` — either way the image crosses into the executor
    /// without being cloned.
    pub fn submit(
        &self,
        image: impl Into<Arc<Tensor4>>,
    ) -> Result<mpsc::Receiver<ConvResponse>> {
        let image: Arc<Tensor4> = image.into();
        let want = [1, self.in_dims[1], self.in_dims[2], self.in_dims[3]];
        if image.dims != want {
            return Err(err!("image shape {:?} != {:?}", image.dims, want));
        }
        let id = self
            .next_id
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Msg::Run(Job { id, image, enqueued: Instant::now(), reply }))
            .map_err(|_| err!("server stopped"))?;
        Ok(rx)
    }

    /// Stop the executor and collect final statistics. Returns promptly
    /// even when the Stop lands inside the linger window: the executor
    /// flushes the in-flight batch and exits.
    pub fn shutdown(mut self) -> Result<ServerStats> {
        let _ = self.tx.send(Msg::Stop);
        let handle = self.handle.take().expect("not yet joined");
        handle.join().map_err(|_| err!("executor panicked"))?
    }
}

impl Drop for ConvServer {
    fn drop(&mut self) {
        let _ = self.tx.send(Msg::Stop);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    // End-to-end server tests (including the shutdown-under-load
    // regression) live in rust/tests/coordinator_e2e.rs; they run on the
    // built-in native backend, no artifacts required.
}
