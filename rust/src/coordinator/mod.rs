//! L3 coordination: the serving/batching layer and the tiling planner.
//!
//! The paper's contribution is analysis + tiling, so the coordinator is the
//! thin-but-real driver the stack needs: a [`server::ConvServer`] that owns
//! an execution runtime (any [`crate::runtime::ExecBackend`] — native by
//! default, PJRT behind the `pjrt` feature) on a dedicated executor thread,
//! batches single-image requests up to the artifact's compiled batch size,
//! executes, and streams responses back — Python never on this path — plus
//! a [`plan::Planner`] that assigns every layer its communication-optimal
//! blocking (LP tiling, GEMMINI tile, bound diagnostics) ahead of
//! execution.

pub mod plan;
pub mod server;

pub use plan::{plan_layer, LayerPlan, Planner};
pub use server::{ConvServer, Overflow, QueuePolicy, ServerOptions, ServerStats};
