//! Two-phase dense-tableau simplex, generic over an exact or floating
//! scalar field.
//!
//! Used exactly (over [`super::Rat`]) by the HBL exponent LP (§2.3) and in
//! f64 by the log-space blocking LPs (§3.2, §4.2). Bland's rule everywhere:
//! our LPs are tiny and degenerate (many tight rank constraints), so
//! anti-cycling matters more than pivot count.

use std::fmt::Debug;
use std::ops::{Add, Div, Mul, Neg, Sub};

/// The scalar requirements for the tableau.
pub trait Scalar:
    Clone
    + Debug
    + PartialOrd
    + Add<Output = Self>
    + Sub<Output = Self>
    + Mul<Output = Self>
    + Div<Output = Self>
    + Neg<Output = Self>
{
    fn zero() -> Self;
    fn one() -> Self;
    /// Comparison tolerance: exact types return a true zero; floats return
    /// a small epsilon so near-degenerate pivots are treated as zero.
    fn tol() -> Self;
    fn is_pos(&self) -> bool {
        self > &Self::tol()
    }
    fn is_neg(&self) -> bool {
        *self < -Self::tol()
    }
    fn is_zero_ish(&self) -> bool {
        !self.is_pos() && !self.is_neg()
    }
}

impl Scalar for f64 {
    fn zero() -> f64 {
        0.0
    }
    fn one() -> f64 {
        1.0
    }
    fn tol() -> f64 {
        1e-9
    }
}

impl Scalar for super::Rat {
    fn zero() -> Self {
        super::Rat::ZERO
    }
    fn one() -> Self {
        super::Rat::ONE
    }
    fn tol() -> Self {
        super::Rat::ZERO
    }
}

/// Constraint relation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rel {
    Le,
    Ge,
    Eq,
}

/// One row `a · x REL b`.
#[derive(Debug, Clone)]
pub struct Constraint<S> {
    pub coeffs: Vec<S>,
    pub rel: Rel,
    pub rhs: S,
}

/// Optimization direction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Objective {
    Maximize,
    Minimize,
}

/// LP outcome.
#[derive(Debug, Clone, PartialEq)]
pub enum LpResult<S> {
    /// optimal objective value + primal solution
    Optimal { value: S, x: Vec<S> },
    Infeasible,
    Unbounded,
}

impl<S: Scalar> LpResult<S> {
    pub fn optimal(self) -> Option<(S, Vec<S>)> {
        match self {
            LpResult::Optimal { value, x } => Some((value, x)),
            _ => None,
        }
    }
}

/// Solve: optimize `c · x` subject to `constraints`, `x ≥ 0`.
pub fn solve<S: Scalar>(
    objective: Objective,
    c: &[S],
    constraints: &[Constraint<S>],
) -> LpResult<S> {
    let n = c.len();
    for (i, con) in constraints.iter().enumerate() {
        assert_eq!(con.coeffs.len(), n, "constraint {i} arity mismatch");
    }
    // Internally always maximize.
    let cmax: Vec<S> = match objective {
        Objective::Maximize => c.to_vec(),
        Objective::Minimize => c.iter().map(|v| -v.clone()).collect(),
    };

    let m = constraints.len();
    // Normalize rows to rhs >= 0.
    let rows: Vec<(Vec<S>, Rel, S)> = constraints
        .iter()
        .map(|con| {
            if con.rhs.is_neg() {
                let flipped = match con.rel {
                    Rel::Le => Rel::Ge,
                    Rel::Ge => Rel::Le,
                    Rel::Eq => Rel::Eq,
                };
                (
                    con.coeffs.iter().map(|v| -v.clone()).collect(),
                    flipped,
                    -con.rhs.clone(),
                )
            } else {
                (con.coeffs.clone(), con.rel, con.rhs.clone())
            }
        })
        .collect();

    // Column layout: [x (n)] [slack/surplus (one per Le/Ge)] [artificial].
    let mut n_slack = 0;
    let mut n_art = 0;
    for (_, rel, _) in &rows {
        match rel {
            Rel::Le => n_slack += 1,
            Rel::Ge => {
                n_slack += 1;
                n_art += 1;
            }
            Rel::Eq => n_art += 1,
        }
    }
    let total = n + n_slack + n_art;
    // tableau[m][total+1], last column = rhs
    let mut t: Vec<Vec<S>> = vec![vec![S::zero(); total + 1]; m];
    let mut basis: Vec<usize> = vec![0; m];
    let mut art_cols: Vec<usize> = Vec::new();
    {
        let mut s_at = n;
        let mut a_at = n + n_slack;
        for (i, (coeffs, rel, rhs)) in rows.iter().enumerate() {
            for (j, v) in coeffs.iter().enumerate() {
                t[i][j] = v.clone();
            }
            t[i][total] = rhs.clone();
            match rel {
                Rel::Le => {
                    t[i][s_at] = S::one();
                    basis[i] = s_at;
                    s_at += 1;
                }
                Rel::Ge => {
                    t[i][s_at] = -S::one();
                    s_at += 1;
                    t[i][a_at] = S::one();
                    basis[i] = a_at;
                    art_cols.push(a_at);
                    a_at += 1;
                }
                Rel::Eq => {
                    t[i][a_at] = S::one();
                    basis[i] = a_at;
                    art_cols.push(a_at);
                    a_at += 1;
                }
            }
        }
    }

    // ---- Phase 1: minimize sum of artificials (maximize its negation,
    // i.e. phase-1 costs c_j = -1 on artificial columns) ----
    if n_art > 0 {
        // reduced-cost row: z_j = -c_j + Σ_{basic i} c_{basis[i]}·t[i][j]
        //                       = δ_art(j) - Σ_{i: basis[i] artificial} t[i][j]
        let mut z: Vec<S> = vec![S::zero(); total + 1];
        for &ac in &art_cols {
            z[ac] = S::one();
        }
        for (i, &b) in basis.iter().enumerate() {
            if art_cols.contains(&b) {
                for j in 0..=total {
                    z[j] = z[j].clone() - t[i][j].clone();
                }
            }
        }
        if !pivot_loop(&mut t, &mut basis, &mut z, total) {
            return LpResult::Unbounded; // cannot happen in phase 1
        }
        // z[total] = -(sum of artificials); feasible iff it reached zero
        if z[total].is_neg() {
            return LpResult::Infeasible;
        }
        // Drive any artificial still in the basis out (degenerate rows).
        for i in 0..m {
            if art_cols.contains(&basis[i]) {
                if let Some(j) = (0..n + n_slack)
                    .find(|&j| !t[i][j].is_zero_ish() && !art_cols.contains(&j))
                {
                    pivot(&mut t, &mut basis, i, j, total);
                } // else: row is all-zero over real vars; harmless.
            }
        }
    }

    // ---- Phase 2: maximize cmax ----
    // reduced costs: z_j = (c_B · B^-1 A_j) - c_j, expressed via tableau
    let mut z: Vec<S> = vec![S::zero(); total + 1];
    for j in 0..n {
        z[j] = -cmax[j].clone();
    }
    for (i, &b) in basis.iter().enumerate() {
        if b < n && !cmax[b].is_zero_ish() {
            let cb = cmax[b].clone();
            for j in 0..=total {
                z[j] = z[j].clone() + cb.clone() * t[i][j].clone();
            }
        }
    }
    // Forbid artificial columns re-entering: set their reduced cost huge by
    // simply never selecting them in the pivot loop (handled via mask).
    let art_mask: Vec<bool> = (0..total).map(|j| art_cols.contains(&j)).collect();
    if !pivot_loop_masked(&mut t, &mut basis, &mut z, total, &art_mask) {
        return LpResult::Unbounded;
    }

    let mut x = vec![S::zero(); n];
    for (i, &b) in basis.iter().enumerate() {
        if b < n {
            x[b] = t[i][total].clone();
        }
    }
    let value = match objective {
        Objective::Maximize => z[total].clone(),
        Objective::Minimize => -z[total].clone(),
    };
    LpResult::Optimal { value, x }
}

/// Gauss pivot at (row, col).
fn pivot<S: Scalar>(
    t: &mut [Vec<S>],
    basis: &mut [usize],
    row: usize,
    col: usize,
    total: usize,
) {
    let p = t[row][col].clone();
    for v in t[row].iter_mut() {
        *v = v.clone() / p.clone();
    }
    for i in 0..t.len() {
        if i != row && !t[i][col].is_zero_ish() {
            let f = t[i][col].clone();
            for j in 0..=total {
                let sub = f.clone() * t[row][j].clone();
                t[i][j] = t[i][j].clone() - sub;
            }
        }
    }
    basis[row] = col;
}

fn pivot_obj<S: Scalar>(t: &[Vec<S>], z: &mut [S], row: usize, col: usize, total: usize) {
    if !z[col].is_zero_ish() {
        let f = z[col].clone();
        for j in 0..=total {
            let sub = f.clone() * t[row][j].clone();
            z[j] = z[j].clone() - sub;
        }
    }
}

fn pivot_loop<S: Scalar>(
    t: &mut [Vec<S>],
    basis: &mut [usize],
    z: &mut [S],
    total: usize,
) -> bool {
    let mask = vec![false; total];
    pivot_loop_masked(t, basis, z, total, &mask)
}

/// Bland's-rule pivot loop. Returns false on unboundedness.
fn pivot_loop_masked<S: Scalar>(
    t: &mut [Vec<S>],
    basis: &mut [usize],
    z: &mut [S],
    total: usize,
    masked: &[bool],
) -> bool {
    loop {
        // entering: smallest index with positive reduced profit (z_j < 0 in
        // the "z-row carries -c + cB B^-1 A" convention means improvement
        // when z_j negative; we store so that positive z[total] grows —
        // choose column with z_j negative).
        let enter = (0..total).find(|&j| !masked[j] && z[j].is_neg());
        let Some(col) = enter else { return true };
        // leaving: min ratio rhs / a_ij over a_ij > 0, Bland tie-break.
        let mut best: Option<(usize, S)> = None;
        for i in 0..t.len() {
            if t[i][col].is_pos() {
                let ratio = t[i][total].clone() / t[i][col].clone();
                best = match best {
                    None => Some((i, ratio)),
                    Some((bi, br)) => {
                        if ratio < br || (ratio == br && basis[i] < basis[bi]) {
                            Some((i, ratio))
                        } else {
                            Some((bi, br))
                        }
                    }
                };
            }
        }
        let Some((row, _)) = best else { return false };
        pivot(t, basis, row, col, total);
        pivot_obj(t, z, row, col, total);
    }
}

#[cfg(test)]
mod tests {
    use super::super::Rat;
    use super::*;

    fn le(coeffs: Vec<f64>, rhs: f64) -> Constraint<f64> {
        Constraint { coeffs, rel: Rel::Le, rhs }
    }

    #[test]
    fn max_simple_2d() {
        // max 3x + 5y st x<=4, 2y<=12, 3x+2y<=18 -> (2,6), value 36
        let r = solve(
            Objective::Maximize,
            &[3.0, 5.0],
            &[
                le(vec![1.0, 0.0], 4.0),
                le(vec![0.0, 2.0], 12.0),
                le(vec![3.0, 2.0], 18.0),
            ],
        );
        let (v, x) = r.optimal().unwrap();
        assert!((v - 36.0).abs() < 1e-9);
        assert!((x[0] - 2.0).abs() < 1e-9 && (x[1] - 6.0).abs() < 1e-9);
    }

    #[test]
    fn min_with_ge_constraints() {
        // min x + y st x + 2y >= 4, 3x + y >= 6 -> x=8/5, y=6/5, value 14/5
        let r = solve(
            Objective::Minimize,
            &[1.0, 1.0],
            &[
                Constraint { coeffs: vec![1.0, 2.0], rel: Rel::Ge, rhs: 4.0 },
                Constraint { coeffs: vec![3.0, 1.0], rel: Rel::Ge, rhs: 6.0 },
            ],
        );
        let (v, x) = r.optimal().unwrap();
        assert!((v - 2.8).abs() < 1e-9, "v={v}");
        assert!((x[0] - 1.6).abs() < 1e-9 && (x[1] - 1.2).abs() < 1e-9);
    }

    #[test]
    fn equality_constraint() {
        // max x + 2y st x + y = 3, x <= 2 -> x in [0,2]; best y=3-x with
        // obj x + 2(3-x) = 6 - x -> x=0, value 6
        let r = solve(
            Objective::Maximize,
            &[1.0, 2.0],
            &[
                Constraint { coeffs: vec![1.0, 1.0], rel: Rel::Eq, rhs: 3.0 },
                le(vec![1.0, 0.0], 2.0),
            ],
        );
        let (v, x) = r.optimal().unwrap();
        assert!((v - 6.0).abs() < 1e-9);
        assert!(x[0].abs() < 1e-9 && (x[1] - 3.0).abs() < 1e-9);
    }

    #[test]
    fn infeasible_detected() {
        let r = solve(
            Objective::Maximize,
            &[1.0],
            &[
                le(vec![1.0], 1.0),
                Constraint { coeffs: vec![1.0], rel: Rel::Ge, rhs: 2.0 },
            ],
        );
        assert_eq!(r, LpResult::Infeasible);
    }

    #[test]
    fn unbounded_detected() {
        let r = solve(Objective::Maximize, &[1.0], &[
            Constraint { coeffs: vec![-1.0], rel: Rel::Le, rhs: 1.0 },
        ]);
        assert_eq!(r, LpResult::Unbounded);
    }

    #[test]
    fn negative_rhs_normalized() {
        // x >= 2 written as -x <= -2
        let r = solve(
            Objective::Minimize,
            &[1.0],
            &[le(vec![-1.0], -2.0)],
        );
        let (v, _) = r.optimal().unwrap();
        assert!((v - 2.0).abs() < 1e-9);
    }

    #[test]
    fn exact_rational_solution() {
        // The HBL-style LP: min sI+sF+sO st pairwise sums >= 1 — optimum is
        // exactly (1/2, 1/2, 1/2), value 3/2.
        let ge = |coeffs: Vec<i128>, rhs: i128| Constraint {
            coeffs: coeffs.into_iter().map(Rat::int).collect(),
            rel: Rel::Ge,
            rhs: Rat::int(rhs),
        };
        let r = solve(
            Objective::Minimize,
            &[Rat::ONE, Rat::ONE, Rat::ONE],
            &[
                ge(vec![1, 1, 0], 1),
                ge(vec![1, 0, 1], 1),
                ge(vec![0, 1, 1], 1),
            ],
        );
        let (v, x) = r.optimal().unwrap();
        assert_eq!(v, Rat::new(3, 2));
        assert_eq!(x, vec![Rat::new(1, 2); 3]);
    }

    #[test]
    fn degenerate_does_not_cycle() {
        // classic degenerate LP; Bland's rule must terminate
        let r = solve(
            Objective::Maximize,
            &[0.75, -150.0, 0.02, -6.0],
            &[
                le(vec![0.25, -60.0, -0.04, 9.0], 0.0),
                le(vec![0.5, -90.0, -0.02, 3.0], 0.0),
                le(vec![0.0, 0.0, 1.0, 0.0], 1.0),
            ],
        );
        let (v, _) = r.optimal().unwrap();
        assert!((v - 0.05).abs() < 1e-9, "v={v}");
    }
}
