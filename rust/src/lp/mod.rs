//! Linear programming substrate.
//!
//! An exact-rational ([`Rat`]) and floating ([`f64`]) two-phase simplex.
//! Consumers:
//!
//! * [`crate::hbl`] — minimizes `Σ sⱼ` over the HBL constraint polytope
//!   (needs exact arithmetic: the optimum is `(2/3, 2/3, 2/3)` and a tight
//!   certificate matters),
//! * [`crate::tiling`] — the log-space blocking LPs of §3.2 and §4.2 (f64).

pub mod rational;
pub mod simplex;

pub use rational::Rat;
pub use simplex::{solve, Constraint, LpResult, Objective, Rel, Scalar};
