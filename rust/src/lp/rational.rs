//! Exact rational arithmetic over i128.
//!
//! The HBL exponent LP (paper §2.3) and the subgroup rank computations must
//! be exact: the optimal exponents are rationals like 2/3 and a floating
//! point simplex could mis-certify a tight constraint. Problem sizes are
//! tiny (d ≤ 9, a handful of constraints) so i128 never overflows in
//! practice; all operations are checked and panic loudly if it ever would.

use std::cmp::Ordering;
use std::fmt;
use std::ops::{Add, AddAssign, Div, Mul, Neg, Sub};

/// A reduced fraction num/den with den > 0.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Rat {
    num: i128,
    den: i128,
}

fn gcd(mut a: i128, mut b: i128) -> i128 {
    a = a.abs();
    b = b.abs();
    while b != 0 {
        let t = a % b;
        a = b;
        b = t;
    }
    a
}

impl Rat {
    pub const ZERO: Rat = Rat { num: 0, den: 1 };
    pub const ONE: Rat = Rat { num: 1, den: 1 };

    pub fn new(num: i128, den: i128) -> Rat {
        assert!(den != 0, "zero denominator");
        let g = gcd(num, den).max(1);
        let sign = if den < 0 { -1 } else { 1 };
        Rat { num: sign * num / g, den: sign * den / g }
    }

    pub fn int(n: i128) -> Rat {
        Rat { num: n, den: 1 }
    }

    pub fn num(&self) -> i128 {
        self.num
    }

    pub fn den(&self) -> i128 {
        self.den
    }

    pub fn is_zero(&self) -> bool {
        self.num == 0
    }

    pub fn is_neg(&self) -> bool {
        self.num < 0
    }

    pub fn is_pos(&self) -> bool {
        self.num > 0
    }

    pub fn abs(&self) -> Rat {
        Rat { num: self.num.abs(), den: self.den }
    }

    pub fn recip(&self) -> Rat {
        assert!(self.num != 0, "reciprocal of zero");
        Rat::new(self.den, self.num)
    }

    pub fn to_f64(&self) -> f64 {
        self.num as f64 / self.den as f64
    }

    fn checked(num: Option<i128>, den: Option<i128>) -> Rat {
        match (num, den) {
            (Some(n), Some(d)) => Rat::new(n, d),
            _ => panic!("rational overflow (i128)"),
        }
    }
}

impl Add for Rat {
    type Output = Rat;
    fn add(self, o: Rat) -> Rat {
        // integer fast path (the overwhelmingly common case in RREF over
        // small-integer bases — see EXPERIMENTS.md §Perf)
        if self.den == 1 && o.den == 1 {
            return Rat {
                num: self.num.checked_add(o.num).expect("rational overflow"),
                den: 1,
            };
        }
        // cross-reduce first to keep magnitudes small
        let g = gcd(self.den, o.den).max(1);
        let (da, db) = (self.den / g, o.den / g);
        Rat::checked(
            self.num
                .checked_mul(db)
                .and_then(|x| o.num.checked_mul(da).and_then(|y| x.checked_add(y))),
            self.den.checked_mul(db),
        )
    }
}

impl AddAssign for Rat {
    fn add_assign(&mut self, o: Rat) {
        *self = *self + o;
    }
}

impl Sub for Rat {
    type Output = Rat;
    fn sub(self, o: Rat) -> Rat {
        self + (-o)
    }
}

impl Neg for Rat {
    type Output = Rat;
    fn neg(self) -> Rat {
        Rat { num: -self.num, den: self.den }
    }
}

impl Mul for Rat {
    type Output = Rat;
    fn mul(self, o: Rat) -> Rat {
        // integer and zero fast paths
        if self.num == 0 || o.num == 0 {
            return Rat::ZERO;
        }
        if self.den == 1 && o.den == 1 {
            return Rat {
                num: self.num.checked_mul(o.num).expect("rational overflow"),
                den: 1,
            };
        }
        // cross-cancel
        let g1 = gcd(self.num, o.den).max(1);
        let g2 = gcd(o.num, self.den).max(1);
        Rat::checked(
            (self.num / g1).checked_mul(o.num / g2),
            (self.den / g2).checked_mul(o.den / g1),
        )
    }
}

impl Div for Rat {
    type Output = Rat;
    fn div(self, o: Rat) -> Rat {
        self * o.recip()
    }
}

impl PartialOrd for Rat {
    fn partial_cmp(&self, o: &Rat) -> Option<Ordering> {
        Some(self.cmp(o))
    }
}

impl Ord for Rat {
    fn cmp(&self, o: &Rat) -> Ordering {
        // dens positive, so compare num*oden vs onum*den
        let l = self.num.checked_mul(o.den).expect("rational overflow");
        let r = o.num.checked_mul(self.den).expect("rational overflow");
        l.cmp(&r)
    }
}

impl fmt::Display for Rat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.den == 1 {
            write!(f, "{}", self.num)
        } else {
            write!(f, "{}/{}", self.num, self.den)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reduction_and_sign() {
        assert_eq!(Rat::new(2, 4), Rat::new(1, 2));
        assert_eq!(Rat::new(1, -2), Rat::new(-1, 2));
        assert_eq!(Rat::new(-3, -6), Rat::new(1, 2));
        assert_eq!(Rat::new(0, 5), Rat::ZERO);
    }

    #[test]
    fn arithmetic() {
        let a = Rat::new(1, 2);
        let b = Rat::new(1, 3);
        assert_eq!(a + b, Rat::new(5, 6));
        assert_eq!(a - b, Rat::new(1, 6));
        assert_eq!(a * b, Rat::new(1, 6));
        assert_eq!(a / b, Rat::new(3, 2));
        assert_eq!(-a, Rat::new(-1, 2));
    }

    #[test]
    fn ordering() {
        assert!(Rat::new(1, 3) < Rat::new(1, 2));
        assert!(Rat::new(-1, 2) < Rat::ZERO);
        assert!(Rat::new(7, 7) == Rat::ONE);
    }

    #[test]
    fn recip_and_zero() {
        assert_eq!(Rat::new(2, 3).recip(), Rat::new(3, 2));
        assert!(Rat::ZERO.is_zero());
        assert!(Rat::new(-1, 9).is_neg());
    }

    #[test]
    #[should_panic(expected = "reciprocal of zero")]
    fn recip_zero_panics() {
        let _ = Rat::ZERO.recip();
    }

    #[test]
    fn to_f64() {
        assert!((Rat::new(2, 3).to_f64() - 0.6666666).abs() < 1e-6);
    }

    #[test]
    fn display() {
        assert_eq!(Rat::new(3, 1).to_string(), "3");
        assert_eq!(Rat::new(-2, 3).to_string(), "-2/3");
    }
}
