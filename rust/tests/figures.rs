//! Integration: every paper table/figure harness produces data with the
//! paper's qualitative shape (DESIGN.md §4 experiment index).

use convbound::conv::{resnet50_layers, Precision};
use convbound::gemmini::GemminiConfig;
use convbound::hbl::{analyze_7nl, analyze_small_filter};
use convbound::lp::Rat;
use convbound::report::{
    default_mem_sweep, default_proc_sweep, fig2_series, fig3_series, fig4_rows,
};
use convbound::util::stats::geomean;

/// §3.1 table: the machinery rediscovers the paper's exponents.
#[test]
fn section_3_1_table() {
    let sol = analyze_7nl(2, 2).expect("7NL exponent LP feasible");
    assert_eq!(sol.total, Rat::int(2));
    // the four distinct constraint patterns of the paper's table exist
    let names = ["I", "F", "O"];
    let printed: Vec<String> = sol.constraints.iter().map(|c| c.pretty(&names)).collect();
    for want in ["1 ≤ s_I + s_O", "1 ≤ s_I + s_F", "1 ≤ s_F + s_O", "2 ≤ s_I + s_F + s_O"] {
        assert!(printed.iter().any(|p| p == want), "missing {want}");
    }
    assert_eq!(
        analyze_small_filter().expect("small-filter LP feasible").total,
        Rat::new(3, 2)
    );
}

/// Figure 2: sequential model shapes at batch 1000, pI=pF=1, pO=2.
#[test]
fn figure2_shape() {
    let p = Precision::paper_mixed();
    let layers = resnet50_layers(1000);

    for l in &layers[..2] {
        let rows = fig2_series(&l.shape, p, &default_mem_sweep());
        for (m, ratios) in &rows {
            for (name, r) in ratios {
                assert!(r.is_finite() && *r > 0.45, "{} {name} at M={m}: {r}", l.name);
            }
            // "communication volumes are a constant multiple of the bound":
            // nothing drifts beyond 4 orders of magnitude
            assert!(ratios.iter().all(|(_, r)| *r < 1e4), "{} at M={m}", l.name);
        }
        // naive never beats blocking at realistic memory sizes
        let at_64k = &rows.iter().find(|(m, _)| *m == 65536.0).unwrap().1;
        assert!(at_64k[0].1 > at_64k[2].1, "naive must exceed blocking");
    }

    // conv2_x: blocking beats im2col for sufficiently large M (σ = 1)
    let conv2 = &layers[1];
    let rows = fig2_series(&conv2.shape, p, &default_mem_sweep());
    assert!(
        rows.iter().any(|(_, r)| r[2].1 < r[1].1),
        "expected a blocking/im2col crossover for conv2_x"
    );

    // blocking and im2col scale better in M than fft/winograd
    let first = &rows.first().unwrap().1;
    let last = &rows.last().unwrap().1;
    let improvement = |i: usize| first[i].1 / last[i].1;
    assert!(improvement(2) > improvement(4), "blocking vs fft scaling");
    assert!(improvement(1) > improvement(3), "im2col vs winograd scaling");
}

/// Figure 3: parallel model shapes.
#[test]
fn figure3_shape() {
    let p = Precision::paper_mixed();
    let layers = resnet50_layers(1000);
    for l in &layers[..2] {
        let rows = fig3_series(&l.shape, p, &default_proc_sweep(), 1e6);
        let mut blocking_wins = 0;
        for (pp, ratios) in &rows {
            for (name, r) in ratios {
                assert!(r.is_finite() && *r >= 0.0, "{} {name} at P={pp}: {r}", l.name);
            }
            if ratios[2].1 <= ratios[1].1 {
                blocking_wins += 1;
            }
            // winograd & fft remain far from the bound relative to im2col
            assert!(ratios[1].1 <= ratios[3].1 * 2.0, "im2col vs winograd at P={pp}");
        }
        // "blocking outperforms im2col considerably"
        assert!(
            blocking_wins * 2 >= rows.len(),
            "{}: blocking won only {blocking_wins}/{}",
            l.name,
            rows.len()
        );
    }
}

/// Figure 4: GEMMINI, ours vs vendor, batch 1000 (slow-ish: ~1 s).
#[test]
fn figure4_shape() {
    let cfg = GemminiConfig::default();
    let rows = fig4_rows(1000, &cfg, false);
    assert_eq!(rows.len(), 5);

    // communication: geomean strictly below vendor; early layers strict wins
    let comm: Vec<f64> = rows.iter().map(|r| r.comm_ratio()).collect();
    assert!(geomean(&comm) < 0.95, "geomean comm {comm:?}");
    assert!(comm[0] < 0.95 && comm[1] < 0.95, "conv1/conv2 must win comm");

    // cycles: wins on the low-utilization early layers
    assert!(rows[0].cycle_ratio() < 1.0, "conv1 cycles");
    assert!(rows[1].cycle_ratio() < 1.0, "conv2 cycles");

    // the paper's regression mechanism exists on a high-utilization layer…
    let worst = rows
        .iter()
        .map(|r| r.cycle_ratio())
        .fold(0.0_f64, f64::max);
    assert!(worst > 1.0, "expected a cycle regression somewhere (paper: conv5 124%)");

    // …and the §5 extra constraint repairs the small-image layer
    let fixed = fig4_rows(1000, &cfg, true);
    assert!(
        fixed[4].cycle_ratio() < rows[4].cycle_ratio(),
        "conv5 constraint must reduce cycles: {} -> {}",
        rows[4].cycle_ratio(),
        fixed[4].cycle_ratio()
    );

    // MAC conservation everywhere
    for (r, l) in rows.iter().zip(resnet50_layers(1000)) {
        assert_eq!(r.ours.macs, l.shape.updates(), "{}", r.name);
        assert_eq!(r.vendor.macs, l.shape.updates(), "{}", r.name);
    }
}

/// §5 text: the optimizer solves in milliseconds what NMaximize took ~5 s
/// and ~400 iterations for.
#[test]
fn tile_optimizer_speed() {
    use convbound::tiling::{optimize_gemmini_tiling, OptOptions};
    let cfg = GemminiConfig::default();
    let t0 = std::time::Instant::now();
    for l in resnet50_layers(1000) {
        let _ = optimize_gemmini_tiling(&l.shape, &cfg, OptOptions::default());
    }
    let dt = t0.elapsed();
    assert!(dt.as_secs_f64() < 5.0, "5 layers took {dt:?} (paper: 5 s for ONE)");
}
