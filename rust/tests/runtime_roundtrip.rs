//! Integration: the execution layer over the native backend — builtin
//! manifest, on-disk manifests without HLO files, shape derivation, caching
//! and the error paths. Runs with no `artifacts/` directory, no Python and
//! no PJRT.
//!
//! Numerics: `blocked` executes the seven-loop nest, `im2col` executes a
//! patch-matrix + GEMM path, and `tiled` executes the kernels/ LP-blocked
//! engine — three independent accumulation orders, so cross-kind agreement
//! is a real cross-validation.
//!
//! With the `pjrt` feature and a populated `artifacts/` directory, the
//! original AOT round-trip (PJRT vs the naive oracle) runs as well.

use convbound::conv::{conv7nl_naive, ConvPass, ConvShape, Tensor4};
use convbound::runtime::{ArtifactSpec, Manifest, Runtime};

/// Recover the ConvShape of a single-layer artifact through the manifest's
/// one authoritative (validated) inversion.
fn shape_of(spec: &ArtifactSpec) -> ConvShape {
    spec.layer_shape().expect("single-layer spec")
}

fn dims4(v: &[usize]) -> [usize; 4] {
    [v[0], v[1], v[2], v[3]]
}

#[test]
fn builtin_layer_artifacts_match_naive_oracle() {
    let mut rt = Runtime::builtin();
    assert_eq!(rt.platform(), "native-cpu");

    let layer_keys: Vec<String> = rt
        .manifest()
        .artifacts
        .iter()
        .filter(|a| a.kind == "blocked" || a.kind == "im2col")
        .map(|a| a.key())
        .collect();
    assert!(layer_keys.len() >= 3, "expected several layer artifacts");

    for key in layer_keys {
        let spec = rt.manifest().find(&key).unwrap().clone();
        let shape = shape_of(&spec);
        let x = Tensor4::randn(dims4(&spec.inputs[0]), 7);
        let w = Tensor4::randn(dims4(&spec.inputs[1]), 8);

        let got = rt.run_loading(&key, &[&x, &w]).expect(&key);
        let want = conv7nl_naive(&x, &w, &shape);

        let rel = got.rel_l2(&want);
        assert!(
            rel < 1e-5,
            "{key}: rel L2 error {rel} vs naive oracle (shape {shape})"
        );
        assert_eq!(got.dims.to_vec(), spec.output);
    }
}

#[test]
fn tiled_builtin_artifacts_match_naive_oracle() {
    // kind "tiled" routes through the kernels/ LP-blocked engine — a third
    // independent accumulation order, validated per builtin layer.
    let mut rt = Runtime::builtin();
    let tiled_keys: Vec<String> = rt
        .manifest()
        .artifacts
        .iter()
        .filter(|a| a.kind == "tiled")
        .map(|a| a.key())
        .collect();
    assert!(tiled_keys.len() >= 2, "builtin manifest must expose tiled kinds");
    for key in tiled_keys {
        let spec = rt.manifest().find(&key).unwrap().clone();
        let shape = shape_of(&spec);
        let x = Tensor4::randn(dims4(&spec.inputs[0]), 17);
        let w = Tensor4::randn(dims4(&spec.inputs[1]), 18);
        let got = rt.run_loading(&key, &[&x, &w]).expect(&key);
        let want = conv7nl_naive(&x, &w, &shape);
        let rel = got.rel_l2(&want);
        assert!(rel < 1e-4, "{key}: rel L2 error {rel} vs naive oracle");
        assert_eq!(got.dims.to_vec(), spec.output);
    }
}

#[test]
fn builtin_gradient_artifacts_match_training_oracles_bitwise() {
    // the training kinds run the pass-generic tiled engine natively: no
    // artifacts directory, no PJRT, bitwise vs the conv/training.rs
    // oracles (the backward accumulation-order contract), traffic
    // surfaced through the same Runtime::traffic entry as forward tiled
    let mut rt = Runtime::builtin();
    let grad_keys: Vec<String> = rt
        .manifest()
        .artifacts
        .iter()
        .filter(|a| a.kind == "dfilter" || a.kind == "dinput")
        .map(|a| a.key())
        .collect();
    assert!(grad_keys.len() >= 4, "builtin manifest must expose training kinds");
    for key in grad_keys {
        let spec = rt.manifest().find(&key).unwrap().clone();
        let pass = ConvPass::parse(&spec.kind).expect("gradient kind");
        let shape = spec.pass_shape(pass).expect("gradient spec inverts");
        let a = Tensor4::randn(dims4(&spec.inputs[0]), 61);
        let b = Tensor4::randn(dims4(&spec.inputs[1]), 62);
        let got = rt.run_loading(&key, &[&a, &b]).expect(&key);
        let want = pass.naive_oracle(&a, &b, &shape);
        assert_eq!(got.dims.to_vec(), spec.output, "{key}");
        assert_eq!(
            got.max_abs_diff(&want),
            0.0,
            "{key}: native gradient diverged from the oracle"
        );
        let t = rt.traffic(&key).expect("gradient kinds are instrumented");
        assert!(t.input_words > 0 && t.output_words > 0, "{key}");
    }
}

#[test]
fn blocked_and_im2col_agree_with_each_other() {
    let mut rt = Runtime::builtin();
    let spec = rt.manifest().find("unit3x3/blocked").unwrap().clone();
    let x = Tensor4::randn(dims4(&spec.inputs[0]), 21);
    let w = Tensor4::randn(dims4(&spec.inputs[1]), 22);
    let a = rt.run_loading("unit3x3/blocked", &[&x, &w]).unwrap();
    let b = rt.run_loading("unit3x3/im2col", &[&x, &w]).unwrap();
    let rel = a.rel_l2(&b);
    assert!(rel < 1e-5, "blocked vs im2col rel_l2={rel}");
}

#[test]
fn strided_builtin_layer_round_trips() {
    // unit5x5 is strided (σ = 2): exercises the shape derivation and the
    // strided indexing of the native kernel.
    let mut rt = Runtime::builtin();
    let spec = rt.manifest().find("unit5x5/blocked").unwrap().clone();
    let shape = shape_of(&spec);
    assert_eq!(shape.s_w, 2);
    let x = Tensor4::randn(dims4(&spec.inputs[0]), 31);
    let w = Tensor4::randn(dims4(&spec.inputs[1]), 32);
    let got = rt.run_loading("unit5x5/blocked", &[&x, &w]).expect("run");
    let want = conv7nl_naive(&x, &w, &shape);
    assert!(got.rel_l2(&want) < 1e-5);
}

#[test]
fn dir_backed_native_runtime_needs_no_hlo_files() {
    // A manifest.json on disk with NO .hlo.txt files next to it: the
    // native backend executes from the spec alone.
    let dir = std::env::temp_dir().join("convbound_native_dir_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"batch": 2, "artifacts": [
            {"name": "t", "kind": "blocked", "path": "missing.hlo.txt",
             "inputs": [[2,3,8,8],[3,4,3,3]], "output": [2,4,5,5],
             "updates": 5400}]}"#,
    )
    .unwrap();

    let mut rt = Runtime::native(&dir).expect("native runtime over dir");
    let spec = rt.manifest().find("t/blocked").unwrap().clone();
    let shape = shape_of(&spec);
    let x = Tensor4::randn(dims4(&spec.inputs[0]), 41);
    let w = Tensor4::randn(dims4(&spec.inputs[1]), 42);
    let got = rt.run_loading("t/blocked", &[&x, &w]).expect("run");
    let want = conv7nl_naive(&x, &w, &shape);
    assert!(got.rel_l2(&want) < 1e-5);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn runtime_failure_injection() {
    // unknown artifact key
    let mut rt = Runtime::builtin();
    assert!(rt.load("missing/kind").is_err());

    // run before load
    assert!(rt.run("unit3x3/blocked", &[]).is_err());

    // wrong input count and wrong shapes
    let spec = rt.manifest().find("unit3x3/blocked").unwrap().clone();
    rt.load("unit3x3/blocked").unwrap();
    let x = Tensor4::randn(dims4(&spec.inputs[0]), 1);
    assert!(rt.run("unit3x3/blocked", &[&x]).is_err(), "one input must fail");
    let bad = Tensor4::zeros([1, 1, 1, 1]);
    assert!(rt.run("unit3x3/blocked", &[&x, &bad]).is_err(), "bad filter shape");

    // nonexistent artifact dir
    assert!(Runtime::new("/nonexistent/path").is_err());

    // corrupt manifest
    let dir = std::env::temp_dir().join("convbound_corrupt_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), "{not json").unwrap();
    assert!(Runtime::new(&dir).is_err());

    // a spec that is not a consistent conv layer must fail at load
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"batch": 1, "artifacts": [{"name": "ghost", "kind": "blocked",
            "path": "ghost.hlo.txt", "inputs": [[1,1,3,3],[1,1,1,1]],
            "output": [1,1,3,3], "updates": 9}]}"#,
    )
    .unwrap();
    let mut rt2 = Runtime::native(&dir).expect("manifest parses");
    assert!(rt2.load("ghost/blocked").is_err(), "inconsistent spec must fail");

    // kinds the native backend cannot execute point at the pjrt feature
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"batch": 1, "artifacts": [{"name": "net", "kind": "network",
            "path": "net.hlo.txt", "inputs": [[1,3,17,17],[3,8,5,5]],
            "output": [1,8,7,7], "updates": 1}]}"#,
    )
    .unwrap();
    let mut rt3 = Runtime::native(&dir).expect("manifest parses");
    let e = rt3.load("net/network").unwrap_err().to_string();
    assert!(e.contains("pjrt"), "error should mention the pjrt feature: {e}");
    std::fs::remove_dir_all(&dir).ok();
}

// ---------------------------------------------------------------------
// PJRT round-trip against compiled artifacts (feature-gated; needs
// `make artifacts`).
// ---------------------------------------------------------------------

#[cfg(feature = "pjrt")]
mod pjrt_roundtrip {
    use super::*;

    fn artifact_dir() -> std::path::PathBuf {
        std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn every_single_layer_artifact_matches_naive_oracle() {
        if !artifact_dir().join("manifest.json").exists() {
            eprintln!("SKIP: no artifacts/ — run `make artifacts`");
            return;
        }
        let mut rt = Runtime::new(artifact_dir()).expect("runtime");
        let platform = rt.platform().to_lowercase();
        assert!(
            platform.contains("cpu") || platform.contains("host"),
            "unexpected platform {platform}"
        );
        let layer_keys: Vec<String> = rt
            .manifest()
            .artifacts
            .iter()
            .filter(|a| a.kind == "blocked" || a.kind == "im2col")
            .map(|a| a.key())
            .collect();
        for key in layer_keys {
            let spec = rt.manifest().find(&key).unwrap().clone();
            let shape = shape_of(&spec);
            let x = Tensor4::randn(dims4(&spec.inputs[0]), 7);
            let w = Tensor4::randn(dims4(&spec.inputs[1]), 8);
            let got = rt.run_loading(&key, &[&x, &w]).expect(&key);
            let want = conv7nl_naive(&x, &w, &shape);
            let rel = got.rel_l2(&want);
            assert!(rel < 1e-5, "{key}: rel {rel}");
        }
    }

    #[test]
    fn blocked_and_im2col_agree_for_every_artifact_pair() {
        if !artifact_dir().join("manifest.json").exists() {
            eprintln!("SKIP: no artifacts/ — run `make artifacts`");
            return;
        }
        let mut rt = Runtime::new(artifact_dir()).expect("runtime");
        let names: Vec<String> = rt
            .manifest()
            .artifacts
            .iter()
            .filter(|a| a.kind == "blocked")
            .map(|a| a.name.clone())
            .collect();
        assert!(!names.is_empty());
        for name in names {
            if rt.manifest().find(&format!("{name}/im2col")).is_none() {
                continue;
            }
            let spec =
                rt.manifest().find(&format!("{name}/blocked")).unwrap().clone();
            let x = Tensor4::randn(dims4(&spec.inputs[0]), 21);
            let w = Tensor4::randn(dims4(&spec.inputs[1]), 22);
            let a = rt.run_loading(&format!("{name}/blocked"), &[&x, &w]).unwrap();
            let b = rt.run_loading(&format!("{name}/im2col"), &[&x, &w]).unwrap();
            let rel = a.rel_l2(&b);
            assert!(rel < 1e-5, "{name}: blocked vs im2col rel_l2={rel}");
        }
    }

    #[test]
    fn gradient_artifacts_match_naive_oracles() {
        use convbound::conv::{dfilter_naive, dinput_naive};
        if !artifact_dir().join("manifest.json").exists() {
            eprintln!("SKIP: no artifacts/ — run `make artifacts`");
            return;
        }
        let mut rt = Runtime::new(artifact_dir()).expect("runtime");
        let fwd = match rt.manifest().find("unit3x3/blocked") {
            Some(s) => s.clone(),
            None => return,
        };
        let shape = shape_of(&fwd);

        // dFilter: inputs (x, dOut) -> dF
        if rt.manifest().find("unit3x3/dfilter").is_some() {
            let spec = rt.manifest().find("unit3x3/dfilter").unwrap().clone();
            let x = Tensor4::randn(dims4(&spec.inputs[0]), 31);
            let g = Tensor4::randn(dims4(&spec.inputs[1]), 32);
            let full = ConvShape { n: spec.inputs[0][0] as u64, ..shape };
            let got = rt.run_loading("unit3x3/dfilter", &[&x, &g]).expect("dfilter");
            let want = dfilter_naive(&x, &g, &full);
            let rel = got.rel_l2(&want);
            assert!(rel < 1e-5, "dfilter rel_l2 {rel}");
        } else {
            eprintln!("SKIP dfilter: artifact absent (regenerate artifacts)");
        }

        // dInput: inputs (dOut, w) -> dIn
        if rt.manifest().find("unit3x3/dinput").is_some() {
            let spec = rt.manifest().find("unit3x3/dinput").unwrap().clone();
            let od = spec.output.clone();
            let g = Tensor4::randn(dims4(&spec.inputs[0]), 33);
            let w = Tensor4::randn(dims4(&spec.inputs[1]), 34);
            let full = ConvShape { n: spec.inputs[0][0] as u64, ..shape };
            let got = rt.run_loading("unit3x3/dinput", &[&g, &w]).expect("dinput");
            let want = dinput_naive(&g, &w, &full, od[2], od[3]);
            let rel = got.rel_l2(&want);
            assert!(rel < 1e-5, "dinput rel_l2 {rel}");
        } else {
            eprintln!("SKIP dinput: artifact absent (regenerate artifacts)");
        }
    }

    /// Zero-pad a tensor's spatial dims up to (tw, th).
    fn pad_spatial(t: &Tensor4, tw: usize, th: usize) -> Tensor4 {
        assert!(tw >= t.dims[2] && th >= t.dims[3]);
        let mut out = Tensor4::zeros([t.dims[0], t.dims[1], tw, th]);
        for a in 0..t.dims[0] {
            for b in 0..t.dims[1] {
                for c in 0..t.dims[2] {
                    for d in 0..t.dims[3] {
                        *out.at_mut(a, b, c, d) = t.at(a, b, c, d);
                    }
                }
            }
        }
        out
    }

    #[test]
    fn network_artifact_matches_layerwise_oracle() {
        if !artifact_dir().join("manifest.json").exists() {
            eprintln!("SKIP: no artifacts/ — run `make artifacts`");
            return;
        }
        let mut rt = Runtime::new(artifact_dir()).expect("runtime");
        let spec = match rt.manifest().find("tiny_resnet/network") {
            Some(s) => s.clone(),
            None => {
                eprintln!("SKIP: no network artifact");
                return;
            }
        };
        let batch = spec.inputs[0][0] as u64;
        // tiny_resnet geometry — must mirror model.tiny_resnet_specs()
        let layers = [
            ConvShape::new(batch, 3, 12, 15, 15, 5, 5, 2, 2),
            ConvShape::new(batch, 12, 16, 12, 12, 3, 3, 1, 1),
            ConvShape::new(batch, 16, 32, 5, 5, 3, 3, 2, 2),
        ];
        assert_eq!(spec.inputs.len(), 1 + layers.len());

        let tensors: Vec<Tensor4> = spec
            .inputs
            .iter()
            .enumerate()
            .map(|(i, d)| Tensor4::randn([d[0], d[1], d[2], d[3]], 100 + i as u64))
            .collect();
        let refs: Vec<&Tensor4> = tensors.iter().collect();
        let out = rt.run_loading("tiny_resnet/network", &refs).expect("network run");
        assert_eq!(out.dims.to_vec(), spec.output);

        // layerwise oracle: pad-to-input -> conv -> relu, mirroring model.py
        let mut act = tensors[0].clone();
        for (li, shape) in layers.iter().enumerate() {
            let want_w = shape.in_w() as usize;
            let want_h = shape.in_h() as usize;
            if act.dims[2] < want_w || act.dims[3] < want_h {
                act = pad_spatial(&act, want_w, want_h);
            }
            let w = &tensors[1 + li];
            act = conv7nl_naive(&act, w, shape);
            for v in act.data.iter_mut() {
                *v = v.max(0.0);
            }
        }
        let rel = out.rel_l2(&act);
        assert!(rel < 1e-4, "network vs layerwise oracle rel_l2={rel}");
    }
}

#[test]
fn manifest_find_semantics_hold_for_builtin() {
    let m = Manifest::builtin(4);
    // exact key
    assert!(m.find("unit3x3/blocked").is_some());
    // bare name is ambiguous for unit3x3 (blocked + im2col)
    assert!(m.find("unit3x3").is_none());
    // bare name unique for unit1x1
    assert!(m.find("unit1x1").is_some());
    assert!(m.find("missing").is_none());
}
