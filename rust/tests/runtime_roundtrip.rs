//! Integration: the full AOT bridge.
//!
//! python/compile/aot.py lowered JAX+Pallas convolutions to HLO text; here
//! the Rust PJRT CPU client loads, compiles and executes every artifact and
//! the numerics are validated against the crate's own naive 7NL CNN oracle.
//!
//! Requires `make artifacts` to have run (skipped with a message otherwise).

use convbound::conv::{conv7nl_naive, ConvShape, Tensor4};
use convbound::runtime::Runtime;

fn artifact_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifact_dir().join("manifest.json").exists()
}

/// Recover the ConvShape of a single-layer artifact from its manifest entry
/// (input is paper-convention sized: WI = σw·wO + wF).
fn shape_of(spec: &convbound::runtime::ArtifactSpec) -> ConvShape {
    let i = &spec.inputs[0];
    let f = &spec.inputs[1];
    let o = &spec.output;
    ConvShape::new(
        o[0] as u64, f[0] as u64, f[1] as u64, o[2] as u64, o[3] as u64,
        f[2] as u64, f[3] as u64,
        ((i[2] - f[2]) / o[2]) as u64,
        ((i[3] - f[3]) / o[3]) as u64,
    )
}

#[test]
fn every_single_layer_artifact_matches_naive_oracle() {
    if !have_artifacts() {
        eprintln!("SKIP: no artifacts/ — run `make artifacts`");
        return;
    }
    let mut rt = Runtime::new(artifact_dir()).expect("runtime");
    let platform = rt.platform().to_lowercase();
    assert!(
        platform.contains("cpu") || platform.contains("host"),
        "unexpected platform {platform}"
    );

    let layer_keys: Vec<String> = rt
        .manifest()
        .artifacts
        .iter()
        .filter(|a| a.kind == "blocked" || a.kind == "im2col")
        .map(|a| a.key())
        .collect();
    assert!(layer_keys.len() >= 4, "expected several layer artifacts");

    for key in layer_keys {
        let spec = rt.manifest().find(&key).unwrap().clone();
        let shape = shape_of(&spec);
        let xd = spec.inputs[0].clone();
        let wd = spec.inputs[1].clone();
        let x = Tensor4::randn([xd[0], xd[1], xd[2], xd[3]], 7);
        let w = Tensor4::randn([wd[0], wd[1], wd[2], wd[3]], 8);

        let got = rt.run_loading(&key, &[&x, &w]).expect(&key);
        let want = conv7nl_naive(&x, &w, &shape);

        let rel = got.rel_l2(&want);
        assert!(
            rel < 1e-5,
            "{key}: rel L2 error {rel} vs naive oracle (shape {shape})"
        );
    }
}

#[test]
fn blocked_and_im2col_agree_with_each_other() {
    if !have_artifacts() {
        eprintln!("SKIP: no artifacts/ — run `make artifacts`");
        return;
    }
    let mut rt = Runtime::new(artifact_dir()).expect("runtime");
    let names: Vec<String> = rt
        .manifest()
        .artifacts
        .iter()
        .filter(|a| a.kind == "blocked")
        .map(|a| a.name.clone())
        .collect();
    assert!(!names.is_empty());
    for name in names {
        let spec = rt.manifest().find(&format!("{name}/blocked")).unwrap().clone();
        let xd = spec.inputs[0].clone();
        let wd = spec.inputs[1].clone();
        let x = Tensor4::randn([xd[0], xd[1], xd[2], xd[3]], 21);
        let w = Tensor4::randn([wd[0], wd[1], wd[2], wd[3]], 22);
        let a = rt.run_loading(&format!("{name}/blocked"), &[&x, &w]).unwrap();
        let b = rt.run_loading(&format!("{name}/im2col"), &[&x, &w]).unwrap();
        let rel = a.rel_l2(&b);
        assert!(rel < 1e-5, "{name}: blocked vs im2col rel_l2={rel}");
    }
}

#[test]
fn gradient_artifacts_match_naive_oracles() {
    use convbound::conv::{dfilter_naive, dinput_naive};
    if !have_artifacts() {
        eprintln!("SKIP: no artifacts/ — run `make artifacts`");
        return;
    }
    let mut rt = Runtime::new(artifact_dir()).expect("runtime");
    let fwd = match rt.manifest().find("unit3x3/blocked") {
        Some(s) => s.clone(),
        None => return,
    };
    let shape = shape_of(&fwd);

    // dFilter: inputs (x, dOut) -> dF
    if rt.manifest().find("unit3x3/dfilter").is_some() {
        let spec = rt.manifest().find("unit3x3/dfilter").unwrap().clone();
        let xd = spec.inputs[0].clone();
        let gd = spec.inputs[1].clone();
        let x = Tensor4::randn([xd[0], xd[1], xd[2], xd[3]], 31);
        let g = Tensor4::randn([gd[0], gd[1], gd[2], gd[3]], 32);
        let full_batch_shape = convbound::conv::ConvShape {
            n: xd[0] as u64, ..shape
        };
        let got = rt.run_loading("unit3x3/dfilter", &[&x, &g]).expect("dfilter");
        let want = dfilter_naive(&x, &g, &full_batch_shape);
        let rel = got.rel_l2(&want);
        assert!(rel < 1e-5, "dfilter rel_l2 {rel}");
    } else {
        eprintln!("SKIP dfilter: artifact absent (regenerate artifacts)");
    }

    // dInput: inputs (dOut, w) -> dIn
    if rt.manifest().find("unit3x3/dinput").is_some() {
        let spec = rt.manifest().find("unit3x3/dinput").unwrap().clone();
        let gd = spec.inputs[0].clone();
        let wd = spec.inputs[1].clone();
        let od = spec.output.clone();
        let g = Tensor4::randn([gd[0], gd[1], gd[2], gd[3]], 33);
        let w = Tensor4::randn([wd[0], wd[1], wd[2], wd[3]], 34);
        let full_batch_shape = convbound::conv::ConvShape {
            n: gd[0] as u64, ..shape
        };
        let got = rt.run_loading("unit3x3/dinput", &[&g, &w]).expect("dinput");
        let want = dinput_naive(&g, &w, &full_batch_shape, od[2], od[3]);
        let rel = got.rel_l2(&want);
        assert!(rel < 1e-5, "dinput rel_l2 {rel}");
    } else {
        eprintln!("SKIP dinput: artifact absent (regenerate artifacts)");
    }
}

#[test]
fn runtime_failure_injection() {
    if !have_artifacts() {
        eprintln!("SKIP: no artifacts/ — run `make artifacts`");
        return;
    }
    // unknown artifact key
    let mut rt = Runtime::new(artifact_dir()).expect("runtime");
    assert!(rt.load("missing/kind").is_err());

    // run before load
    assert!(rt.run("unit3x3/blocked", &[]).is_err());

    // wrong input count and wrong shapes
    let spec = rt.manifest().find("unit3x3/blocked").unwrap().clone();
    rt.load("unit3x3/blocked").unwrap();
    let xd = spec.inputs[0].clone();
    let x = Tensor4::randn([xd[0], xd[1], xd[2], xd[3]], 1);
    assert!(rt.run("unit3x3/blocked", &[&x]).is_err(), "one input must fail");
    let bad = Tensor4::zeros([1, 1, 1, 1]);
    assert!(rt.run("unit3x3/blocked", &[&x, &bad]).is_err(), "bad filter shape");

    // nonexistent artifact dir
    assert!(Runtime::new("/nonexistent/path").is_err());

    // corrupt manifest
    let dir = std::env::temp_dir().join("convbound_corrupt_manifest");
    std::fs::create_dir_all(&dir).unwrap();
    std::fs::write(dir.join("manifest.json"), "{not json").unwrap();
    assert!(Runtime::new(&dir).is_err());

    // manifest pointing at a missing HLO file
    std::fs::write(
        dir.join("manifest.json"),
        r#"{"batch": 1, "artifacts": [{"name": "ghost", "kind": "blocked",
            "path": "ghost.hlo.txt", "inputs": [[1,1,3,3],[1,1,1,1]],
            "output": [1,1,3,3], "updates": 9}]}"#,
    )
    .unwrap();
    let mut rt2 = Runtime::new(&dir).expect("manifest parses");
    assert!(rt2.load("ghost/blocked").is_err(), "missing HLO file must fail");

    // garbage HLO text
    std::fs::write(dir.join("ghost.hlo.txt"), "this is not HLO").unwrap();
    assert!(rt2.load("ghost/blocked").is_err(), "unparsable HLO must fail");
    std::fs::remove_dir_all(&dir).ok();
}

/// Zero-pad a tensor's spatial dims up to (tw, th).
fn pad_spatial(t: &Tensor4, tw: usize, th: usize) -> Tensor4 {
    assert!(tw >= t.dims[2] && th >= t.dims[3]);
    let mut out = Tensor4::zeros([t.dims[0], t.dims[1], tw, th]);
    for a in 0..t.dims[0] {
        for b in 0..t.dims[1] {
            for c in 0..t.dims[2] {
                for d in 0..t.dims[3] {
                    *out.at_mut(a, b, c, d) = t.at(a, b, c, d);
                }
            }
        }
    }
    out
}

#[test]
fn network_artifact_matches_layerwise_oracle() {
    if !have_artifacts() {
        eprintln!("SKIP: no artifacts/ — run `make artifacts`");
        return;
    }
    let mut rt = Runtime::new(artifact_dir()).expect("runtime");
    let spec = match rt.manifest().find("tiny_resnet/network") {
        Some(s) => s.clone(),
        None => {
            eprintln!("SKIP: no network artifact");
            return;
        }
    };
    let batch = spec.inputs[0][0] as u64;
    // tiny_resnet geometry — must mirror model.tiny_resnet_specs()
    let layers = [
        ConvShape::new(batch, 3, 12, 15, 15, 5, 5, 2, 2),
        ConvShape::new(batch, 12, 16, 12, 12, 3, 3, 1, 1),
        ConvShape::new(batch, 16, 32, 5, 5, 3, 3, 2, 2),
    ];
    assert_eq!(spec.inputs.len(), 1 + layers.len());

    let tensors: Vec<Tensor4> = spec
        .inputs
        .iter()
        .enumerate()
        .map(|(i, d)| Tensor4::randn([d[0], d[1], d[2], d[3]], 100 + i as u64))
        .collect();
    let refs: Vec<&Tensor4> = tensors.iter().collect();
    let out = rt.run_loading("tiny_resnet/network", &refs).expect("network run");
    assert_eq!(out.dims.to_vec(), spec.output);

    // layerwise oracle: pad-to-input -> conv -> relu, mirroring model.py
    let mut act = tensors[0].clone();
    for (li, shape) in layers.iter().enumerate() {
        let want_w = shape.in_w() as usize;
        let want_h = shape.in_h() as usize;
        if act.dims[2] < want_w || act.dims[3] < want_h {
            act = pad_spatial(&act, want_w, want_h);
        }
        let w = &tensors[1 + li];
        act = conv7nl_naive(&act, w, shape);
        for v in act.data.iter_mut() {
            *v = v.max(0.0);
        }
    }
    let rel = out.rel_l2(&act);
    assert!(rel < 1e-4, "network vs layerwise oracle rel_l2={rel}");
}
