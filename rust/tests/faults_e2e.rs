//! Integration: the fault-tolerance layer under deterministic injected
//! faults (DESIGN.md §12) — kernel panics degrade to verified fallbacks
//! with bitwise-correct outputs, bounded queues shed or backpressure,
//! deadlines shed expired work, dispatch errors fail only their batch,
//! and the books balance through all of it.
//!
//! Fault state is process-global, so every test here arms its plan with
//! [`faults::arm_scoped`], which serializes the tests on a global gate
//! and disarms on drop. This binary is its own process, so arming can
//! never perturb the lib/kernel test binaries.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use convbound::conv::{conv7nl_naive, Tensor4};
use convbound::coordinator::{
    ConvServer, Overflow, QueuePolicy, ServerOptions,
};
use convbound::runtime::{ArtifactSpec, Manifest, NetworkStage};
use convbound::testkit::faults::{self, FaultPlan, Site};
use convbound::util::error::ErrorKind;

fn builtin_spec(key: &str) -> ArtifactSpec {
    Manifest::builtin(convbound::runtime::manifest::BUILTIN_BATCH)
        .find(key)
        .unwrap_or_else(|| panic!("builtin key {key}"))
        .clone()
}

fn weights_for(spec: &ArtifactSpec, seed: u64) -> Vec<Tensor4> {
    spec.inputs[1..]
        .iter()
        .enumerate()
        .map(|(i, d)| {
            Tensor4::randn([d[0], d[1], d[2], d[3]], seed + i as u64)
        })
        .collect()
}

fn image_for(spec: &ArtifactSpec, seed: u64) -> Tensor4 {
    let d = &spec.inputs[0];
    Tensor4::randn([1, d[1], d[2], d[3]], seed)
}

/// Injected per-tile panics inside the fused network executor must never
/// fail a request: the native backend's FallbackExec catches them, reruns
/// the batch on the layer-by-layer naive oracle, and the response stays
/// bitwise identical to the per-image oracle. The process survives every
/// panic, and ServerStats reports the panics and degradations.
#[test]
fn injected_tile_panics_degrade_to_fallback_and_stay_bitwise() {
    let _guard = faults::arm_scoped(
        FaultPlan::parse("exec:panic:every=3").expect("spec"),
    );
    let m = Manifest::builtin(convbound::runtime::manifest::BUILTIN_BATCH);
    let net = m.network("tiny_resnet").expect("builtin network").clone();
    let spec = builtin_spec("tiny_resnet/network");
    let weights = weights_for(&spec, 60);
    let server = ConvServer::start_builtin_network(
        "tiny_resnet/network",
        weights.clone(),
        Duration::from_millis(3),
    )
    .expect("network server under faults");

    // per-image oracle: the same chain at batch 1
    let one_img_stages: Vec<NetworkStage> = net
        .stages
        .iter()
        .map(|st| NetworkStage {
            shape: st.shape.with_batch(1),
            precision: st.precision,
        })
        .collect();
    let wrefs: Vec<&Tensor4> = weights.iter().collect();

    let n_req = spec.inputs[0][0] + 1; // forces a second (padded) batch
    let images: Vec<Tensor4> =
        (0..n_req).map(|i| image_for(&spec, 800 + i as u64)).collect();
    let pending: Vec<_> = images
        .iter()
        .map(|img| server.submit(img.clone()).expect("submit"))
        .collect();
    for (img, rx) in images.iter().zip(pending) {
        let resp = rx
            .recv()
            .expect("response")
            .expect("request must survive injected panics");
        let want = convbound::kernels::naive_network(
            img,
            &wrefs,
            &one_img_stages,
        );
        assert_eq!(
            resp.output.max_abs_diff(&want),
            0.0,
            "degraded execution must stay bitwise-correct"
        );
    }
    let stats = server.shutdown().expect("server survives injected panics");
    assert_eq!(stats.requests, n_req as u64);
    assert_eq!(stats.failed, 0, "panics must degrade, not fail requests");
    assert!(stats.panicked >= 1, "the injected panics were caught: {stats:?}");
    assert!(stats.degraded >= 1, "the batches reran on the fallback: {stats:?}");
    assert!(faults::fired(Site::Exec) >= 1);
}

/// A bounded `Shed` queue over a deterministically slow backend: the
/// queue depth can never exceed capacity, excess submits fail fast with
/// typed `QueueFull` errors, and the client's books agree with the
/// server's at shutdown (`submitted == ok + failed + expired + shed`).
#[test]
fn shed_policy_bounds_queue_depth_and_books_balance() {
    let _guard = faults::arm_scoped(
        FaultPlan::parse("queue:stall:ms=40").expect("spec"),
    );
    let spec = builtin_spec("unit3x3/blocked");
    let cap = 3u64;
    let server = ConvServer::start_builtin_opts(
        "unit3x3/blocked",
        weights_for(&spec, 7),
        ServerOptions {
            queue: Some(QueuePolicy { capacity: cap, overflow: Overflow::Shed }),
            deadline: None,
            linger: Duration::from_millis(1),
        },
    )
    .expect("shed server");

    let total = 32u64;
    let mut pending = Vec::new();
    let mut client_shed = 0u64;
    for i in 0..total {
        match server.submit(image_for(&spec, 100 + i)) {
            Ok(rx) => pending.push(rx),
            Err(e) => {
                assert_eq!(e.kind(), ErrorKind::QueueFull, "got: {e}");
                assert!(e.to_string().contains("queue full"), "got: {e}");
                client_shed += 1;
            }
        }
    }
    let mut ok = 0u64;
    for rx in pending {
        rx.recv().expect("response").expect("admitted requests complete");
        ok += 1;
    }
    let stats = server.shutdown().expect("shutdown");
    assert!(
        client_shed >= 1,
        "a 40ms-per-batch backend behind a 3-deep queue must shed some of \
         32 fast submits"
    );
    assert_eq!(stats.shed, client_shed);
    assert_eq!(stats.requests, ok);
    assert!(
        stats.peak_queue_depth <= cap,
        "Shed must bound the queue: peak {} > capacity {cap}",
        stats.peak_queue_depth
    );
    assert_eq!(
        stats.requests + stats.failed + stats.expired + stats.shed,
        total,
        "the books must balance: {stats:?}"
    );
}

/// A bounded `Block` queue applies backpressure instead of shedding:
/// every submit eventually lands, the enqueue-time depth never exceeds
/// capacity, and nothing is shed.
#[test]
fn block_policy_applies_backpressure() {
    let _guard = faults::arm_scoped(
        FaultPlan::parse("queue:stall:ms=25").expect("spec"),
    );
    let spec = builtin_spec("unit3x3/blocked");
    let cap = 2u64;
    let server = Arc::new(
        ConvServer::start_builtin_opts(
            "unit3x3/blocked",
            weights_for(&spec, 9),
            ServerOptions {
                queue: Some(QueuePolicy {
                    capacity: cap,
                    overflow: Overflow::Block,
                }),
                deadline: None,
                linger: Duration::from_millis(1),
            },
        )
        .expect("block server"),
    );

    let completed = Arc::new(AtomicU64::new(0));
    let mut handles = Vec::new();
    for t in 0..4u64 {
        let server = Arc::clone(&server);
        let completed = Arc::clone(&completed);
        let spec = spec.clone();
        handles.push(std::thread::spawn(move || {
            let pending: Vec<_> = (0..8u64)
                .map(|i| {
                    server
                        .submit(image_for(&spec, t * 100 + i))
                        .expect("Block submit never sheds")
                })
                .collect();
            for rx in pending {
                rx.recv().expect("response").expect("ok");
                completed.fetch_add(1, Ordering::SeqCst);
            }
        }));
    }
    for h in handles {
        h.join().expect("submitter thread");
    }
    let server = Arc::into_inner(server).expect("sole owner");
    let stats = server.shutdown().expect("shutdown");
    assert_eq!(completed.load(Ordering::SeqCst), 32);
    assert_eq!(stats.requests, 32);
    assert_eq!(stats.shed, 0, "Block never sheds");
    assert!(
        stats.peak_queue_depth <= cap,
        "backpressure must bound the queue: peak {} > capacity {cap}",
        stats.peak_queue_depth
    );
}

/// Per-request deadlines shed expired work at dequeue — before it wastes
/// a batch slot — with typed `DeadlineExceeded` replies, and the expiries
/// are booked separately from failures.
#[test]
fn deadlines_shed_expired_work_at_dequeue() {
    let _guard = faults::arm_scoped(
        FaultPlan::parse("queue:stall:ms=60").expect("spec"),
    );
    let spec = builtin_spec("unit3x3/blocked");
    let server = ConvServer::start_builtin_opts(
        "unit3x3/blocked",
        weights_for(&spec, 11),
        ServerOptions {
            queue: None,
            deadline: Some(Duration::from_millis(10)),
            linger: Duration::from_millis(2),
        },
    )
    .expect("deadline server");

    let total = 12u64;
    let pending: Vec<_> = (0..total)
        .map(|i| server.submit(image_for(&spec, 200 + i)).expect("submit"))
        .collect();
    let mut ok = 0u64;
    let mut expired = 0u64;
    for rx in pending {
        match rx.recv().expect("every request gets a reply") {
            Ok(_) => ok += 1,
            Err(e) => {
                assert_eq!(e.kind(), ErrorKind::DeadlineExceeded, "got: {e}");
                expired += 1;
            }
        }
    }
    let stats = server.shutdown().expect("shutdown");
    assert!(
        expired >= 1,
        "a 60ms-per-batch backend must expire some 10ms-deadline requests"
    );
    assert_eq!(stats.requests, ok);
    assert_eq!(stats.expired, expired);
    assert_eq!(stats.failed, 0);
    assert_eq!(
        stats.requests + stats.expired,
        total,
        "the books must balance: {stats:?}"
    );
}

/// An injected dispatch error on every attempt fails only the affected
/// batches — each request gets a typed error reply, the executor and
/// server survive, and the books still balance.
#[test]
fn dispatch_errors_fail_only_the_batch_and_server_survives() {
    let _guard = faults::arm_scoped(
        FaultPlan::parse("exec:error:every=1").expect("spec"),
    );
    let spec = builtin_spec("unit3x3/blocked");
    let server = ConvServer::start_builtin(
        "unit3x3/blocked",
        weights_for(&spec, 13).remove(0),
        Duration::from_millis(1),
    )
    .expect("server");

    let total = 6u64;
    let pending: Vec<_> = (0..total)
        .map(|i| server.submit(image_for(&spec, 300 + i)).expect("submit"))
        .collect();
    for rx in pending {
        let reply = rx.recv().expect("failed requests still get a reply");
        let e = reply.expect_err("every dispatch attempt was injected to fail");
        assert!(e.to_string().contains("injected fault"), "got: {e}");
    }
    let stats = server.shutdown().expect("server survives failed batches");
    assert_eq!(stats.requests, 0);
    assert_eq!(stats.failed, total);
    assert_eq!(stats.failed + stats.requests, total, "books: {stats:?}");
    // both attempts of each batch consulted the fault point
    assert!(faults::fired(Site::Exec) >= 2);
}

/// A shard worker panic (injected on every kernel tile) must surface from
/// the sharded executor as a typed `WorkerPanicked` error — never a
/// process abort or a hang — for every strategy, so callers can degrade.
/// The barrier-release regression rides along implicitly: if a panicking
/// spatial shard left its peers parked on the exchange barrier, this test
/// would deadlock instead of returning the typed error.
#[test]
fn injected_shard_panics_become_typed_worker_errors() {
    use convbound::conv::{ConvShape, Precision};
    use convbound::kernels::{
        exec_sharded, ShardPlan, ShardStrategy, ShardTrafficCounters,
        TilePlanCache, DEFAULT_TILE_MEM_WORDS,
    };
    let _guard = faults::arm_scoped(
        FaultPlan::parse("exec:panic:every=1").expect("spec"),
    );
    let shape = ConvShape::new(4, 3, 2, 5, 5, 3, 3, 1, 1);
    let stages =
        vec![NetworkStage { shape, precision: Precision::uniform() }];
    let image = Arc::new(Tensor4::randn(
        [
            shape.n as usize,
            shape.c_i as usize,
            shape.in_w() as usize,
            shape.in_h() as usize,
        ],
        1,
    ));
    let filters = vec![Arc::new(Tensor4::randn(shape.filter_dims(), 2))];
    let cache = TilePlanCache::new();
    for strategy in ShardStrategy::ALL {
        let plan = Arc::new(ShardPlan::new(
            &stages,
            strategy,
            2,
            DEFAULT_TILE_MEM_WORDS,
            &cache,
        ));
        let counters = Arc::new(ShardTrafficCounters::new(plan.workers()));
        let e = exec_sharded(&image, &filters, &plan, &counters)
            .expect_err("every tile is injected to panic");
        assert_eq!(e.kind(), ErrorKind::WorkerPanicked, "{strategy:?}: {e}");
    }
    assert!(faults::fired(Site::Exec) >= 3);
}

/// The server seam: a sharded network backend under injected per-tile
/// panics degrades to the layered naive oracle, the answer stays bitwise
/// identical to that oracle, and the fallback shell books both the panic
/// and the degradation — this is the `exec:panic` fault gate ci.sh holds
/// the sharded path to.
#[test]
fn sharded_backend_degrades_to_naive_and_stays_bitwise() {
    use convbound::kernels::{naive_network, ShardStrategy};
    use convbound::runtime::{ExecBackend, NativeBackend, NetworkSpec};
    let _guard = faults::arm_scoped(
        FaultPlan::parse("exec:panic:every=1").expect("spec"),
    );
    let net = NetworkSpec::tiny_resnet(2);
    let spec = ArtifactSpec::for_network(&net);
    let mut be = NativeBackend::with_shards(2, Some(ShardStrategy::Batch));
    let exe = be.load_network(&net, &spec).expect("sharded load");
    let image = Tensor4::randn(net.input_dims(), 5);
    let filters: Vec<Tensor4> = net
        .stages
        .iter()
        .enumerate()
        .map(|(i, st)| Tensor4::randn(st.shape.filter_dims(), 6 + i as u64))
        .collect();
    let mut ins: Vec<&Tensor4> = vec![&image];
    ins.extend(filters.iter());
    let got = exe.execute(&ins).expect("degraded execution succeeds");
    let frefs: Vec<&Tensor4> = filters.iter().collect();
    let want = naive_network(&image, &frefs, &net.stages);
    assert_eq!(
        got.max_abs_diff(&want),
        0.0,
        "degraded sharded answer must be bitwise vs the naive oracle"
    );
    let fs = exe.fault_stats().expect("fallback shell");
    assert!(fs.panicked >= 1, "{fs:?}");
    assert!(fs.degraded >= 1, "{fs:?}");
    assert!(faults::fired(Site::Exec) >= 1);
}

/// `times=1` caps the injection at the first dispatch attempt: the
/// executor's single retry recovers the batch, so the fault fired but no
/// request failed.
#[test]
fn single_retry_recovers_a_once_injected_dispatch_error() {
    let _guard = faults::arm_scoped(
        FaultPlan::parse("exec:error:every=1:times=1").expect("spec"),
    );
    let spec = builtin_spec("unit3x3/blocked");
    let shape = spec
        .layer_shape()
        .expect("single-layer spec")
        .with_batch(1);
    let weights = weights_for(&spec, 17).remove(0);
    let server = ConvServer::start_builtin(
        "unit3x3/blocked",
        weights.clone(),
        Duration::from_millis(1),
    )
    .expect("server");

    let img = image_for(&spec, 400);
    let rx = server.submit(img.clone()).expect("submit");
    let resp = rx
        .recv()
        .expect("response")
        .expect("the retry must recover the batch");
    let want = conv7nl_naive(&img, &weights, &shape);
    assert!(resp.output.rel_l2(&want) < 1e-5);

    let stats = server.shutdown().expect("shutdown");
    assert_eq!(stats.requests, 1);
    assert_eq!(stats.failed, 0, "one injected error + one retry = no failure");
    assert!(faults::fired(Site::Exec) >= 1, "the fault must actually fire");
}
