//! Integration: the L3 coordinator — batching server over the built-in
//! native backend, numerics validated per request against the naive
//! oracle. No artifacts directory, no Python, no PJRT required.

use std::time::Duration;

use convbound::conv::{conv7nl_naive, ConvShape, Tensor4};
use convbound::coordinator::ConvServer;
use convbound::runtime::{ArtifactSpec, Manifest};

const KEY: &str = "unit3x3/blocked";

/// The builtin unit3x3 spec plus the per-image (batch 1) shape for the
/// oracle.
fn layer_spec() -> (ArtifactSpec, ConvShape) {
    let m = Manifest::builtin(convbound::runtime::manifest::BUILTIN_BATCH);
    let spec = m.find(KEY).expect("builtin unit3x3").clone();
    let shape = spec.layer_shape().expect("single-layer spec").with_batch(1);
    (spec, shape)
}

#[test]
fn server_answers_correctly_and_batches() {
    let (spec, shape) = layer_spec();
    let wd = spec.inputs[1].clone();
    let xd = spec.inputs[0].clone();
    let weights = Tensor4::randn([wd[0], wd[1], wd[2], wd[3]], 77);
    let server =
        ConvServer::start_builtin(KEY, weights.clone(), Duration::from_millis(5))
            .expect("server start");
    assert_eq!(server.batch_size(), xd[0]);

    // submit an uneven number of requests (forces a padded final batch)
    let n_req = xd[0] * 2 + 1;
    let images: Vec<Tensor4> = (0..n_req)
        .map(|i| Tensor4::randn([1, xd[1], xd[2], xd[3]], 900 + i as u64))
        .collect();
    let pending: Vec<_> = images
        .iter()
        .map(|img| server.submit(img.clone()).expect("submit"))
        .collect();

    for (img, rx) in images.iter().zip(pending) {
        let resp = rx.recv().expect("response").expect("ok");
        // oracle on the single image
        let want = conv7nl_naive(img, &weights, &shape);
        let rel = resp.output.rel_l2(&want);
        assert!(rel < 1e-5, "request: rel_l2 {rel}");
        assert!(resp.latency.as_secs_f64() < 30.0);
    }

    let stats = server.shutdown().expect("shutdown");
    assert_eq!(stats.requests, n_req as u64);
    assert!(stats.batches >= 3, "expected >= 3 batches, got {}", stats.batches);
    assert!(stats.padded_slots >= 1, "uneven request count must pad");
}

#[test]
fn server_routes_through_tiled_engine() {
    // the same layer served via the "tiled" artifact kind: requests flow
    // through the kernels/ engine and still match the per-image oracle
    let m = Manifest::builtin(convbound::runtime::manifest::BUILTIN_BATCH);
    let spec = m.find("unit3x3/tiled").expect("builtin tiled").clone();
    let shape = spec.layer_shape().expect("single-layer spec").with_batch(1);
    let wd = spec.inputs[1].clone();
    let xd = spec.inputs[0].clone();
    let weights = Tensor4::randn([wd[0], wd[1], wd[2], wd[3]], 55);
    let server = ConvServer::start_builtin(
        "unit3x3/tiled",
        weights.clone(),
        Duration::from_millis(2),
    )
    .expect("tiled server start");
    let images: Vec<Tensor4> = (0..xd[0] + 1)
        .map(|i| Tensor4::randn([1, xd[1], xd[2], xd[3]], 700 + i as u64))
        .collect();
    let pending: Vec<_> = images
        .iter()
        .map(|img| server.submit(img.clone()).expect("submit"))
        .collect();
    for (img, rx) in images.iter().zip(pending) {
        let resp = rx.recv().expect("response").expect("ok");
        let want = conv7nl_naive(img, &weights, &shape);
        let rel = resp.output.rel_l2(&want);
        assert!(rel < 1e-4, "tiled request: rel_l2 {rel}");
    }
    let stats = server.shutdown().expect("shutdown");
    assert_eq!(stats.requests, xd[0] as u64 + 1);
}

#[test]
fn server_serves_whole_network_requests() {
    // whole-network serving: one submit per image, the response is the
    // final stage's activation, validated bitwise against the staged
    // naive oracle per request
    let m = Manifest::builtin(convbound::runtime::manifest::BUILTIN_BATCH);
    let net = m.network("tiny_resnet").expect("builtin network").clone();
    let spec = m.find("tiny_resnet/network").expect("network artifact").clone();
    let weights: Vec<Tensor4> = spec.inputs[1..]
        .iter()
        .enumerate()
        .map(|(i, d)| Tensor4::randn([d[0], d[1], d[2], d[3]], 60 + i as u64))
        .collect();
    let server = ConvServer::start_builtin_network(
        "tiny_resnet/network",
        weights.clone(),
        Duration::from_millis(3),
    )
    .expect("network server start");
    let xd = spec.inputs[0].clone();
    assert_eq!(server.batch_size(), xd[0]);

    // per-image oracle: the same chain at batch 1
    let one_img_stages: Vec<convbound::runtime::NetworkStage> = net
        .stages
        .iter()
        .map(|st| convbound::runtime::NetworkStage {
            shape: st.shape.with_batch(1),
            precision: st.precision,
        })
        .collect();
    let wrefs: Vec<&Tensor4> = weights.iter().collect();

    let n_req = xd[0] + 1; // forces a padded second batch
    let images: Vec<Tensor4> = (0..n_req)
        .map(|i| Tensor4::randn([1, xd[1], xd[2], xd[3]], 800 + i as u64))
        .collect();
    let pending: Vec<_> = images
        .iter()
        .map(|img| server.submit(img.clone()).expect("submit"))
        .collect();
    for (img, rx) in images.iter().zip(pending) {
        let resp = rx.recv().expect("response").expect("ok");
        let want =
            convbound::kernels::naive_network(img, &wrefs, &one_img_stages);
        assert_eq!(
            resp.output.max_abs_diff(&want),
            0.0,
            "network request must match the staged oracle bitwise"
        );
    }
    let stats = server.shutdown().expect("shutdown");
    assert_eq!(stats.requests, n_req as u64);
    assert!(stats.padded_slots >= 1);

    // wrong weight arity is rejected at start
    let one = vec![Tensor4::randn(
        [
            spec.inputs[1][0],
            spec.inputs[1][1],
            spec.inputs[1][2],
            spec.inputs[1][3],
        ],
        9,
    )];
    assert!(ConvServer::start_builtin_network(
        "tiny_resnet/network",
        one,
        Duration::from_millis(1)
    )
    .is_err());
}

#[test]
fn server_serves_gradient_requests_through_training_kind() {
    // gradient serving: one submit per tail loss-gradient slice, the
    // response is the head image gradient from the fused backward sweep,
    // validated bitwise against the chained dInput oracle per request
    // (the backward accumulation-order contract makes every plan bitwise)
    let m = Manifest::builtin(convbound::runtime::manifest::BUILTIN_BATCH);
    let net = m.network("tiny_resnet").expect("builtin network").clone();
    let spec = m.find("tiny_resnet/training").expect("training artifact").clone();
    let weights: Vec<Tensor4> = spec.inputs[1..]
        .iter()
        .enumerate()
        .map(|(i, d)| Tensor4::randn([d[0], d[1], d[2], d[3]], 40 + i as u64))
        .collect();
    let server = ConvServer::start_builtin_training(
        "tiny_resnet/training",
        weights.clone(),
        Duration::from_millis(3),
    )
    .expect("training server start");
    let gd = spec.inputs[0].clone();
    assert_eq!(server.batch_size(), gd[0]);

    // per-request oracle: the same chain at batch 1
    let one_img_stages: Vec<convbound::runtime::NetworkStage> = net
        .stages
        .iter()
        .map(|st| convbound::runtime::NetworkStage {
            shape: st.shape.with_batch(1),
            precision: st.precision,
        })
        .collect();
    let wrefs: Vec<&Tensor4> = weights.iter().collect();

    let n_req = gd[0] + 1; // forces a padded second batch
    let grads: Vec<Tensor4> = (0..n_req)
        .map(|i| Tensor4::randn([1, gd[1], gd[2], gd[3]], 500 + i as u64))
        .collect();
    let pending: Vec<_> = grads
        .iter()
        .map(|g| server.submit(g.clone()).expect("submit"))
        .collect();
    for (g, rx) in grads.iter().zip(pending) {
        let resp = rx.recv().expect("response").expect("ok");
        let want =
            convbound::kernels::naive_network_bwd(g, &wrefs, &one_img_stages);
        assert_eq!(
            resp.output.max_abs_diff(&want),
            0.0,
            "gradient request must match the chained dInput oracle bitwise"
        );
        assert_eq!(resp.output.dims[1..], spec.output[1..]);
    }
    let stats = server.shutdown().expect("shutdown");
    assert_eq!(stats.requests, n_req as u64);
    assert!(stats.padded_slots >= 1);
}

#[test]
fn zero_copy_submit_accepts_shared_images() {
    // submit takes Arc<Tensor4> directly: many requests can share one
    // buffer with no per-submit copies
    let (spec, shape) = layer_spec();
    let wd = spec.inputs[1].clone();
    let xd = spec.inputs[0].clone();
    let weights = Tensor4::randn([wd[0], wd[1], wd[2], wd[3]], 13);
    let server =
        ConvServer::start_builtin(KEY, weights.clone(), Duration::from_millis(2))
            .expect("server");
    let img =
        std::sync::Arc::new(Tensor4::randn([1, xd[1], xd[2], xd[3]], 14));
    let pending: Vec<_> = (0..xd[0])
        .map(|_| server.submit(std::sync::Arc::clone(&img)).expect("submit"))
        .collect();
    let want = conv7nl_naive(&img, &weights, &shape);
    for rx in pending {
        let resp = rx.recv().expect("response").expect("ok");
        assert!(resp.output.rel_l2(&want) < 1e-5);
    }
    server.shutdown().expect("shutdown");
}

#[test]
fn server_rejects_bad_shapes() {
    let (spec, _) = layer_spec();
    let wd = spec.inputs[1].clone();
    let weights = Tensor4::randn([wd[0], wd[1], wd[2], wd[3]], 1);

    // wrong weights shape fails at start
    let bad_w = Tensor4::zeros([1, 1, 1, 1]);
    assert!(ConvServer::start_builtin(KEY, bad_w, Duration::from_millis(1)).is_err());

    // wrong image shape fails at submit
    let server = ConvServer::start_builtin(KEY, weights, Duration::from_millis(1))
        .expect("server");
    assert!(server.submit(Tensor4::zeros([1, 1, 2, 2])).is_err());

    // unknown artifact fails at start
    let wd2 = spec.inputs[1].clone();
    let w2 = Tensor4::randn([wd2[0], wd2[1], wd2[2], wd2[3]], 2);
    assert!(
        ConvServer::start_builtin("nope/blocked", w2, Duration::from_millis(1))
            .is_err()
    );
}

#[test]
fn concurrent_submitters_all_served() {
    let (spec, _) = layer_spec();
    let wd = spec.inputs[1].clone();
    let xd = spec.inputs[0].clone();
    let weights = Tensor4::randn([wd[0], wd[1], wd[2], wd[3]], 5);
    let server = std::sync::Arc::new(
        ConvServer::start_builtin(KEY, weights, Duration::from_millis(2))
            .expect("server"),
    );

    let mut handles = Vec::new();
    for t in 0..4 {
        let server = std::sync::Arc::clone(&server);
        let dims = [1, xd[1], xd[2], xd[3]];
        handles.push(std::thread::spawn(move || {
            for i in 0..8 {
                let img = Tensor4::randn(dims, (t * 100 + i) as u64);
                let rx = server.submit(img).expect("submit");
                let resp = rx.recv().expect("response").expect("ok");
                assert_eq!(resp.output.dims[0], 1);
            }
        }));
    }
    for h in handles {
        h.join().expect("worker");
    }
    let server = std::sync::Arc::into_inner(server).expect("sole owner");
    let stats = server.shutdown().expect("shutdown");
    assert_eq!(stats.requests, 32);
}

/// Regression: a client that drops its reply receiver before (or after)
/// the response is computed must not kill the executor — the worker-side
/// `reply.send` on a closed channel is ignored, and later requests are
/// still served.
#[test]
fn dropped_client_does_not_crash_the_server() {
    let (spec, shape) = layer_spec();
    let wd = spec.inputs[1].clone();
    let xd = spec.inputs[0].clone();
    let weights = Tensor4::randn([wd[0], wd[1], wd[2], wd[3]], 31);
    let server =
        ConvServer::start_builtin(KEY, weights.clone(), Duration::from_millis(2))
            .expect("server");

    // drop the receiver immediately: the executor still runs the job and
    // its reply lands on a closed channel
    let img = Tensor4::randn([1, xd[1], xd[2], xd[3]], 32);
    drop(server.submit(img).expect("submit"));

    // the server keeps serving afterwards
    let img2 = Tensor4::randn([1, xd[1], xd[2], xd[3]], 33);
    let rx = server.submit(img2.clone()).expect("submit after drop");
    let resp = rx.recv().expect("response").expect("ok");
    let want = conv7nl_naive(&img2, &weights, &shape);
    assert!(resp.output.rel_l2(&want) < 1e-5);

    let stats = server.shutdown().expect("shutdown");
    // the dropped request still executed and was booked as completed
    assert_eq!(stats.requests, 2);
    assert_eq!(stats.failed, 0);
}

/// Regression: shutdown under load must return promptly.
///
/// The seed's linger loop handled a `Stop` arriving inside the linger
/// window by only breaking batch assembly; the executor then flushed the
/// batch and re-blocked on `recv()` while `shutdown()` joined with the
/// sender half still alive — a permanent deadlock. This test fails
/// (times out after 10 s) against that logic and passes with the stop
/// flag propagated to the outer loop.
#[test]
fn shutdown_under_load_returns_promptly_and_flushes() {
    let (spec, shape) = layer_spec();
    let wd = spec.inputs[1].clone();
    let xd = spec.inputs[0].clone();
    assert!(xd[0] > 1, "need batch > 1 so a single request leaves the batch unfilled");

    let weights = Tensor4::randn([wd[0], wd[1], wd[2], wd[3]], 3);
    // a linger far longer than the test: the Stop must land inside the
    // linger window, not after it
    let server = ConvServer::start_builtin(KEY, weights.clone(), Duration::from_secs(30))
        .expect("server");

    // fewer images than the batch size -> the batcher lingers
    let img = Tensor4::randn([1, xd[1], xd[2], xd[3]], 4);
    let rx = server.submit(img.clone()).expect("submit");
    // give the executor a moment to pick the job up and enter the window
    std::thread::sleep(Duration::from_millis(100));

    let (done_tx, done_rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let _ = done_tx.send(server.shutdown());
    });
    let stats = done_rx
        .recv_timeout(Duration::from_secs(10))
        .expect("shutdown() must return, not deadlock")
        .expect("shutdown result");

    // the in-flight batch was flushed, not dropped
    assert_eq!(stats.requests, 1);
    assert_eq!(stats.batches, 1);
    assert_eq!(stats.padded_slots as usize, xd[0] - 1);

    let resp = rx
        .recv_timeout(Duration::from_secs(1))
        .expect("in-flight request must still be answered")
        .expect("flushed batch answers ok");
    let want = conv7nl_naive(&img, &weights, &shape);
    assert!(resp.output.rel_l2(&want) < 1e-5);
}

/// The tentpole acceptance gate for the tracing layer: a traced serving
/// run's JSONL log, replayed offline, must reproduce the `ServerStats`
/// the server returned — *exactly*, not approximately. Both sides sort
/// latencies with `f64::total_cmp` and share
/// `util::stats::percentile`, and the JSON number round-trip is
/// shortest-representation exact, so `==` on the floats is the honest
/// assertion.
#[test]
fn traced_server_log_reproduces_server_stats_exactly() {
    use convbound::obs;

    let (spec, _) = layer_spec();
    let wd = spec.inputs[1].clone();
    let xd = spec.inputs[0].clone();
    let weights = Tensor4::randn([wd[0], wd[1], wd[2], wd[3]], 21);
    let path = std::env::temp_dir().join("convbound_e2e_trace.jsonl");
    let path_s = path.to_str().unwrap().to_string();
    // an explicit sink (not the global one): parallel tests in this
    // binary never see each other's events
    let sink = obs::TraceSink::to_file(&path_s).expect("sink");
    let server = ConvServer::start_builtin_traced(
        KEY,
        vec![weights],
        Duration::from_millis(2),
        sink,
    )
    .expect("traced server");

    let n_req = xd[0] * 2 + 1; // uneven: forces a padded final batch
    let pending: Vec<_> = (0..n_req)
        .map(|i| {
            let img =
                Tensor4::randn([1, xd[1], xd[2], xd[3]], 300 + i as u64);
            server.submit(img).expect("submit")
        })
        .collect();
    for rx in pending {
        rx.recv().expect("response").expect("ok");
    }
    let stats = server.shutdown().expect("shutdown");

    let text = std::fs::read_to_string(&path).expect("trace written");
    // structural gate first: every line parses, timestamps are monotone,
    // every request/batch span balances
    let report = obs::check_text(&text).expect("trace check");
    for k in [obs::kind::REQUEST, obs::kind::BATCH, obs::kind::SERVER_STATS] {
        assert!(report.kinds.contains_key(k), "missing '{k}': {:?}", report.kinds);
    }

    // the replay summary must agree with the returned ServerStats
    let s = obs::summarize_text(&text).expect("summarize");
    assert_eq!(s.requests, stats.requests);
    assert_eq!(s.dropped_requests, stats.failed);
    // a healthy run has zero fault activity — on both sides of the replay
    assert_eq!(
        (stats.shed, stats.expired, stats.panicked, stats.degraded),
        (0, 0, 0, 0)
    );
    assert_eq!(
        (s.shed, s.expired, s.panicked, s.degraded),
        (
            stats.shed,
            stats.expired,
            stats.panicked,
            stats.degraded
        )
    );
    assert_eq!(s.batches, stats.batches);
    assert_eq!(s.padded_slots, stats.padded_slots);
    assert_eq!(s.peak_queue_depth, stats.peak_queue_depth);
    assert_eq!(s.latency_p50_ms, stats.latency_p50_ms);
    assert_eq!(s.latency_p95_ms, stats.latency_p95_ms);
    assert_eq!(s.latency_p99_ms, stats.latency_p99_ms);
    assert_eq!(s.total_exec_secs, stats.total_exec_secs);
    // the batch histogram covers every dispatched batch, and the padded
    // final batch shows up as a linger flush
    assert_eq!(s.batch_hist.values().sum::<u64>(), stats.batches);
    assert!(s.linger_flushes >= 1, "uneven load must linger-flush");
    std::fs::remove_file(&path).ok();
}
