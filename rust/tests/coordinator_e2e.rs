//! Integration: the L3 coordinator — batching server over the PJRT
//! runtime, numerics validated per request against the naive oracle.
//! Requires `make artifacts` (skips with a message otherwise).

use std::time::Duration;

use convbound::conv::{conv7nl_naive, ConvShape, Tensor4};
use convbound::coordinator::ConvServer;
use convbound::runtime::Manifest;

fn artifact_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifact_dir().join("manifest.json").exists()
}

fn layer_spec() -> Option<(convbound::runtime::ArtifactSpec, ConvShape)> {
    let m = Manifest::load(artifact_dir().join("manifest.json")).ok()?;
    let spec = m.find("unit3x3/blocked")?.clone();
    let i = &spec.inputs[0];
    let f = &spec.inputs[1];
    let o = &spec.output;
    let shape = ConvShape::new(
        1, f[0] as u64, f[1] as u64, o[2] as u64, o[3] as u64,
        f[2] as u64, f[3] as u64,
        ((i[2] - f[2]) / o[2]) as u64,
        ((i[3] - f[3]) / o[3]) as u64,
    );
    Some((spec, shape))
}

#[test]
fn server_answers_correctly_and_batches() {
    if !have_artifacts() {
        eprintln!("SKIP: no artifacts/ — run `make artifacts`");
        return;
    }
    let (spec, shape) = layer_spec().expect("unit3x3 artifact");
    let wd = spec.inputs[1].clone();
    let xd = spec.inputs[0].clone();
    let weights = Tensor4::randn([wd[0], wd[1], wd[2], wd[3]], 77);
    let server = ConvServer::start(
        artifact_dir(), "unit3x3/blocked", weights.clone(), Duration::from_millis(5),
    )
    .expect("server start");
    assert_eq!(server.batch_size(), xd[0]);

    // submit an uneven number of requests (forces a padded final batch)
    let n_req = xd[0] * 2 + 1;
    let images: Vec<Tensor4> = (0..n_req)
        .map(|i| Tensor4::randn([1, xd[1], xd[2], xd[3]], 900 + i as u64))
        .collect();
    let pending: Vec<_> = images
        .iter()
        .map(|img| server.submit(img.clone()).expect("submit"))
        .collect();

    for (img, rx) in images.iter().zip(pending) {
        let resp = rx.recv().expect("response");
        // oracle on the single image
        let want = conv7nl_naive(img, &weights, &shape);
        let rel = resp.output.rel_l2(&want);
        assert!(rel < 1e-5, "request: rel_l2 {rel}");
        assert!(resp.latency.as_secs_f64() < 30.0);
    }

    let stats = server.shutdown().expect("shutdown");
    assert_eq!(stats.requests, n_req as u64);
    assert!(stats.batches >= 3, "expected >= 3 batches, got {}", stats.batches);
    assert!(stats.padded_slots >= 1, "uneven request count must pad");
}

#[test]
fn server_rejects_bad_shapes() {
    if !have_artifacts() {
        eprintln!("SKIP: no artifacts/ — run `make artifacts`");
        return;
    }
    let (spec, _) = layer_spec().expect("unit3x3 artifact");
    let wd = spec.inputs[1].clone();
    let weights = Tensor4::randn([wd[0], wd[1], wd[2], wd[3]], 1);

    // wrong weights shape fails at start
    let bad_w = Tensor4::zeros([1, 1, 1, 1]);
    assert!(ConvServer::start(
        artifact_dir(), "unit3x3/blocked", bad_w, Duration::from_millis(1)
    )
    .is_err());

    // wrong image shape fails at submit
    let server = ConvServer::start(
        artifact_dir(), "unit3x3/blocked", weights, Duration::from_millis(1),
    )
    .expect("server");
    assert!(server.submit(Tensor4::zeros([1, 1, 2, 2])).is_err());

    // unknown artifact fails at start
    let wd2 = spec.inputs[1].clone();
    let w2 = Tensor4::randn([wd2[0], wd2[1], wd2[2], wd2[3]], 2);
    assert!(ConvServer::start(artifact_dir(), "nope/blocked", w2, Duration::from_millis(1)).is_err());
}

#[test]
fn concurrent_submitters_all_served() {
    if !have_artifacts() {
        eprintln!("SKIP: no artifacts/ — run `make artifacts`");
        return;
    }
    let (spec, _) = layer_spec().expect("unit3x3 artifact");
    let wd = spec.inputs[1].clone();
    let xd = spec.inputs[0].clone();
    let weights = Tensor4::randn([wd[0], wd[1], wd[2], wd[3]], 5);
    let server = std::sync::Arc::new(
        ConvServer::start(
            artifact_dir(), "unit3x3/blocked", weights, Duration::from_millis(2),
        )
        .expect("server"),
    );

    let mut handles = Vec::new();
    for t in 0..4 {
        let server = std::sync::Arc::clone(&server);
        let dims = [1, xd[1], xd[2], xd[3]];
        handles.push(std::thread::spawn(move || {
            for i in 0..8 {
                let img = Tensor4::randn(dims, (t * 100 + i) as u64);
                let rx = server.submit(img).expect("submit");
                let resp = rx.recv().expect("response");
                assert_eq!(resp.output.dims[0], 1);
            }
        }));
    }
    for h in handles {
        h.join().expect("worker");
    }
    let server = std::sync::Arc::into_inner(server).expect("sole owner");
    let stats = server.shutdown().expect("shutdown");
    assert_eq!(stats.requests, 32);
}
