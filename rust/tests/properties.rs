//! Property-based tests over the analysis stack (in-tree testkit; proptest
//! is unavailable offline). Each property encodes a theorem-level invariant
//! from the paper or a conservation law of the simulator.

use std::sync::Arc;

use convbound::bounds::{parallel_bound_terms, sequential_bound, sequential_bound_terms};
use convbound::commvol::seq::blocking_volume;
use convbound::conv::{
    alexnet_layers, conv7nl_naive, paper_operands, pass_operands,
    resnet50_layers, scaled, ConvPass, ConvShape, Precision, Tensor4,
};
use convbound::gemmini::{simulate_layer, GemminiConfig};
use convbound::kernels::{
    axpy, axpy_scalar, conv_network_bwd, conv_network_bwd_counted,
    conv_network_fused, conv_network_fused_counted, conv_network_step_counted,
    conv_pass_tiled, conv_pass_tiled_counted, conv_pass_tiled_parallel,
    conv_tiled_counted, conv_winograd_counted, conv_winograd_parallel,
    expected_pass_traffic, expected_traffic, expected_winograd_traffic,
    naive_network, naive_network_bwd, naive_network_step, winograd_tolerance,
    FusePlan, FusedExec, NetPass, NetTrafficCounters, TilePlan, TilePlanCache,
    Traffic, TrafficCounters, WinoPlan,
};
use convbound::runtime::{NetworkSpec, NetworkStage};
use convbound::util::threadpool::ThreadPool;
use convbound::hbl::{lattice_closure, Mat, Subspace};
use convbound::lp::{solve, Constraint, Objective, Rat, Rel};
use convbound::testkit::{forall, forall_shrink, shrink_u64s, Config};
use convbound::tiling::{
    optimize_gemmini_tiling, parallel_blocking, sequential_blocking, vendor_tiling,
    GemminiTile, OptOptions,
};
use convbound::util::rng::Rng;

fn random_shape(r: &mut Rng) -> ConvShape {
    // modest sizes with the paper's model assumptions enforced
    let s_w = r.range(1, 3);
    let s_h = r.range(1, 3);
    let w_f = r.range(s_w, s_w + 4);
    let h_f = r.range(s_h, s_h + 4);
    let w_o = r.range((w_f + s_w - 1) / s_w, 24).max(1);
    let h_o = r.range((h_f + s_h - 1) / s_h, 24).max(1);
    ConvShape::new(
        r.range(1, 16),
        r.range(1, 48),
        r.range(1, 48),
        w_o,
        h_o,
        w_f,
        h_f,
        s_w,
        s_h,
    )
}

fn random_precision(r: &mut Rng) -> Precision {
    let opts = [0.25, 0.5, 1.0, 2.0, 4.0];
    Precision::new(*r.choose(&opts), *r.choose(&opts), *r.choose(&opts))
}

// ---------------- bounds ----------------

#[test]
fn prop_sequential_bound_monotone_in_memory() {
    forall(
        Config { cases: 120, seed: 11 },
        |r| (random_shape(r), random_precision(r), r.range(64, 1 << 20) as f64),
        |(s, p, m)| {
            sequential_bound(s, *p, *m) >= sequential_bound(s, *p, m * 2.0) - 1e-6
        },
    );
}

#[test]
fn prop_bound_at_least_compulsory_traffic() {
    forall(
        Config { cases: 120, seed: 12 },
        |r| (random_shape(r), random_precision(r), r.range(64, 1 << 22) as f64),
        |(s, p, m)| {
            let t = sequential_bound_terms(s, *p, *m);
            t.max() >= s.footprint_words(*p) - 1e-6
        },
    );
}

#[test]
fn prop_parallel_bound_nonneg_and_decaying_in_p() {
    forall(
        Config { cases: 120, seed: 13 },
        |r| (random_shape(r), random_precision(r), r.range(1, 12)),
        |(s, p, logp)| {
            let m = 4096.0;
            let few = parallel_bound_terms(s, *p, (1u64 << logp) as f64, m).thm22();
            let many = parallel_bound_terms(s, *p, (1u64 << (logp + 1)) as f64, m).thm22();
            few >= 0.0 && many <= few + 1e-6
        },
    );
}

#[test]
fn prop_cp_constant_cases() {
    forall(
        Config { cases: 200, seed: 14 },
        |r| random_precision(r),
        |p| {
            let cp = p.c_p();
            if p.triangle() {
                (cp - p.total().powi(2) / 4.0).abs() < 1e-9
            } else {
                // C_p = p_j (p_k + p_l) < p_T²/4 never holds when triangle
                // fails; also C_p must stay positive
                cp > 0.0
            }
        },
    );
}

// ---------------- HBL machinery ----------------

#[test]
fn prop_subspace_dimension_formula() {
    // dim(U + W) + dim(U ∩ W) = dim U + dim W on random integer spans
    forall(
        Config { cases: 120, seed: 21 },
        |r| {
            let d = r.range(2, 5) as usize;
            let rows_u: Vec<Vec<i128>> = (0..r.range(1, 3))
                .map(|_| (0..d).map(|_| r.range(0, 4) as i128 - 2).collect())
                .collect();
            let rows_w: Vec<Vec<i128>> = (0..r.range(1, 3))
                .map(|_| (0..d).map(|_| r.range(0, 4) as i128 - 2).collect())
                .collect();
            (d, rows_u, rows_w)
        },
        |(d, rows_u, rows_w)| {
            let u = Subspace::span_int(*d, rows_u);
            let w = Subspace::span_int(*d, rows_w);
            u.sum(&w).rank() + u.intersect(&w).rank() == u.rank() + w.rank()
        },
    );
}

#[test]
fn prop_image_rank_bounded() {
    // rank(φ(H)) ≤ min(rank H, rank φ)
    forall(
        Config { cases: 120, seed: 22 },
        |r| {
            let d = r.range(2, 6) as usize;
            let dj = r.range(1, d as u64) as usize;
            let phi: Vec<Vec<i128>> = (0..dj)
                .map(|_| (0..d).map(|_| r.range(0, 5) as i128 - 2).collect())
                .collect();
            let h: Vec<Vec<i128>> = (0..r.range(1, 3))
                .map(|_| (0..d).map(|_| r.range(0, 5) as i128 - 2).collect())
                .collect();
            (d, phi, h)
        },
        |(d, phi, h)| {
            let phi_m = Mat::from_int_rows(phi);
            let sub = Subspace::span_int(*d, h);
            let img = sub.image(&phi_m);
            img.rank() <= sub.rank() && img.rank() <= phi_m.rank()
        },
    );
}

#[test]
fn prop_lattice_closure_is_closed_and_contains_seeds() {
    forall(
        Config { cases: 40, seed: 23 },
        |r| {
            let d = r.range(2, 4) as usize;
            let seeds: Vec<Vec<Vec<i128>>> = (0..r.range(1, 3))
                .map(|_| {
                    (0..r.range(1, 2))
                        .map(|_| (0..d).map(|_| r.range(0, 3) as i128 - 1).collect())
                        .collect()
                })
                .collect();
            (d, seeds)
        },
        |(d, seeds)| {
            let subs: Vec<Subspace> =
                seeds.iter().map(|rows| Subspace::span_int(*d, rows)).collect();
            let lat = lattice_closure(&subs);
            convbound::hbl::lattice::is_closed(&lat)
                && subs.iter().filter(|s| !s.is_zero()).all(|s| lat.contains(s))
        },
    );
}

// ---------------- LP ----------------

#[test]
fn prop_simplex_solution_feasible_and_certified() {
    // random small LPs with box constraints are always feasible/bounded;
    // the returned x must satisfy every constraint and the objective value
    // must match c·x exactly (rational arithmetic)
    forall(
        Config { cases: 80, seed: 31 },
        |r| {
            let n = r.range(2, 4) as usize;
            let m = r.range(1, 4) as usize;
            let c: Vec<i128> = (0..n).map(|_| r.range(0, 5) as i128).collect();
            let rows: Vec<(Vec<i128>, i128)> = (0..m)
                .map(|_| {
                    ((0..n).map(|_| r.range(0, 4) as i128).collect(), r.range(1, 20) as i128)
                })
                .collect();
            (n, c, rows)
        },
        |(n, c, rows)| {
            let mut cons: Vec<Constraint<Rat>> = rows
                .iter()
                .map(|(coef, b)| Constraint {
                    coeffs: coef.iter().map(|&v| Rat::int(v)).collect(),
                    rel: Rel::Le,
                    rhs: Rat::int(*b),
                })
                .collect();
            for i in 0..*n {
                let mut co = vec![Rat::ZERO; *n];
                co[i] = Rat::ONE;
                cons.push(Constraint { coeffs: co, rel: Rel::Le, rhs: Rat::int(50) });
            }
            let obj: Vec<Rat> = c.iter().map(|&v| Rat::int(v)).collect();
            match solve(Objective::Maximize, &obj, &cons) {
                convbound::lp::LpResult::Optimal { value, x } => {
                    let feasible = cons.iter().all(|con| {
                        let lhs = con
                            .coeffs
                            .iter()
                            .zip(&x)
                            .fold(Rat::ZERO, |a, (c, xi)| a + *c * *xi);
                        lhs <= con.rhs
                    });
                    let cx = obj.iter().zip(&x).fold(Rat::ZERO, |a, (c, xi)| a + *c * *xi);
                    feasible && cx == value && x.iter().all(|xi| !xi.is_neg())
                }
                _ => false,
            }
        },
    );
}

// ---------------- tilings ----------------

#[test]
fn prop_sequential_blocking_always_fits() {
    forall(
        Config { cases: 60, seed: 41 },
        |r| {
            let s = random_shape(r);
            let p = random_precision(r);
            let m = r.range(1 << 10, 1 << 20) as f64;
            (s, p, m)
        },
        |(s, p, m)| {
            let b = sequential_blocking(s, *p, *m);
            b.fits(*p, *m) && b.updates_per_tile() >= 1.0
        },
    );
}

#[test]
fn prop_parallel_blocking_respects_processors_and_ranges() {
    forall(
        Config { cases: 60, seed: 42 },
        |r| (random_shape(r), random_precision(r), 1u64 << r.range(0, 12)),
        |(s, p, procs)| {
            let b = parallel_blocking(s, *p, *procs, 1e12);
            let ranges = [s.n, s.c_i, s.c_o, s.w_o, s.h_o, s.w_f, s.h_f];
            b.procs_used <= *procs
                && b.slices.iter().zip(ranges).all(|(&sl, rg)| sl >= 1 && sl <= rg.max(1))
        },
    );
}

#[test]
fn prop_gemmini_tiles_fit_and_optimizer_dominates_vendor_updates() {
    let cfg = GemminiConfig::default();
    forall(
        Config { cases: 40, seed: 43 },
        |r| random_shape(r),
        |s| {
            let ours = optimize_gemmini_tiling(s, &cfg, OptOptions::default());
            let vend = vendor_tiling(s, &cfg);
            let upd = |t: &GemminiTile| t.b_n * t.b_ci * t.b_co * t.b_wo * t.b_ho;
            ours.fits(s, &cfg) && vend.fits(s, &cfg) && upd(&ours) >= upd(&vend)
        },
    );
}

// ---------------- simulator conservation ----------------

#[test]
fn prop_sim_mac_conservation_and_comm_floor() {
    let cfg = GemminiConfig::default();
    forall_shrink(
        Config { cases: 30, seed: 51 },
        |r| {
            let s = random_shape(r);
            vec![s.n, s.c_i, s.c_o, s.w_o, s.h_o, s.w_f, s.h_f, s.s_w, s.s_h]
        },
        |v| {
            let s = ConvShape::new(v[0], v[1], v[2], v[3], v[4], v[5], v[6], v[7], v[8]);
            if !s.paper_assumptions_hold() {
                return true; // generator guard after shrinking
            }
            let tile = optimize_gemmini_tiling(&s, &cfg, OptOptions::default());
            let res = simulate_layer(&s, &cfg, &tile);
            // every update executed exactly once; communication covers at
            // least one write of every output row
            res.macs == s.updates()
                && res.comm_rows
                    >= s.n * s.w_o * s.h_o * ((s.c_o + 15) / 16)
        },
        |v: &Vec<u64>| shrink_u64s(v),
    );
}

// ---------------- tiled execution engine ----------------

/// Shapes that stress the tiled engine: strides > 1, non-square filters,
/// and small prime-ish extents so tile edges are ragged. The paper's
/// `σ ≤ f` model assumption is kept (the blocking LP's split-filter ranges
/// assume it); `f ≤ σ·out` is irrelevant to execution.
fn random_tiled_shape(r: &mut Rng) -> ConvShape {
    let s_w = r.range(1, 3);
    let s_h = r.range(1, 3);
    let w_f = r.range(s_w, s_w + 4);
    let h_f = r.range(s_h, s_h + 3);
    ConvShape::new(
        r.range(1, 4),
        r.range(1, 6),
        r.range(1, 6),
        r.range(2, 11),
        r.range(2, 11),
        w_f,
        h_f,
        s_w,
        s_h,
    )
}

#[test]
fn prop_tiled_kernel_matches_naive_oracle() {
    forall(
        Config { cases: 24, seed: 71 },
        |r| {
            let s = random_tiled_shape(r);
            // small memories force deep, ragged tilings (≥ 512 words keeps
            // tiles big enough that dev-profile runs stay fast)
            let m = (1u64 << r.range(9, 13)) as f64;
            (s, m, r.range(0, 1_000_000))
        },
        |(s, m, seed)| {
            let (x, w) = paper_operands(s, *seed);
            let plan = TilePlan::new(s, Precision::uniform(), *m);
            let counters = TrafficCounters::new();
            let got = conv_tiled_counted(&x, &w, &plan, &counters);
            let want = conv7nl_naive(&x, &w, s);
            let t = counters.snapshot();
            got.rel_l2(&want) < 1e-4
                && t.output_words == s.output_size()
                && t.input_words > 0
                && t.filter_words > 0
        },
    );
}

#[test]
fn prop_tiled_traffic_counters_match_analytic_model() {
    // the engine's measured word traffic equals the tile-grid model exactly
    forall(
        Config { cases: 16, seed: 72 },
        |r| {
            let s = random_tiled_shape(r);
            let m = (1u64 << r.range(9, 14)) as f64;
            (s, m)
        },
        |(s, m)| {
            let (x, w) = paper_operands(s, 7);
            let plan = TilePlan::new(s, Precision::uniform(), *m);
            let counters = TrafficCounters::new();
            conv_tiled_counted(&x, &w, &plan, &counters);
            counters.snapshot() == expected_traffic(&plan)
        },
    );
}

#[test]
fn tiled_matches_naive_on_full_catalog_within_traffic_envelope() {
    // every catalog layer (runnable-size variant), three checks per layer:
    // numerics vs the oracle, exact counter/model agreement, and measured
    // traffic within 2x of the commvol::seq blocking prediction
    let p = Precision::uniform();
    let m = 65536.0;
    for l in resnet50_layers(2).into_iter().chain(alexnet_layers(2)) {
        let s = scaled(l.shape, 4);
        let (x, w) = paper_operands(&s, 101);
        let plan = TilePlan::new(&s, p, m);
        let counters = TrafficCounters::new();
        let got = conv_tiled_counted(&x, &w, &plan, &counters);
        let want = conv7nl_naive(&x, &w, &s);
        let rel = got.rel_l2(&want);
        assert!(rel < 1e-4, "{}: rel_l2 {rel}", l.name);

        let t = counters.snapshot();
        assert_eq!(t, expected_traffic(&plan), "{}", l.name);

        let predicted = blocking_volume(&s, p, m);
        let measured = t.total() as f64;
        assert!(
            measured > 0.0 && measured <= 2.0 * predicted,
            "{}: measured {measured} vs commvol blocking prediction \
             {predicted} ({}x)",
            l.name,
            measured / predicted
        );
    }
}

// ---------------- winograd F(2,3) ----------------

#[test]
fn prop_winograd_matches_naive_within_tolerance_with_exact_traffic() {
    // arbitrary strided/ragged shapes normalize through the polyphase +
    // chunk decomposition; mixed precisions reshape the tile block (never
    // the words); the measured traffic equals the analytic model exactly
    forall(
        Config { cases: 24, seed: 81 },
        |r| {
            let s = random_tiled_shape(r);
            let p = random_precision(r);
            let m = (1u64 << r.range(9, 14)) as f64;
            (s, p, m, r.range(0, 1_000_000))
        },
        |(s, p, m, seed)| {
            let (x, w) = paper_operands(s, *seed);
            let plan = WinoPlan::new(s, *p, *m);
            let counters = TrafficCounters::new();
            let got = conv_winograd_counted(&x, &w, &plan, &counters);
            let want = conv7nl_naive(&x, &w, s);
            let tol = winograd_tolerance(&x, &w, s);
            let t = counters.snapshot();
            got.max_abs_diff(&want) <= tol
                && got.rel_l2(&want) < 1e-4
                && t == expected_winograd_traffic(&plan)
                && t.filter_words == s.filter_size()
                && t.output_words == s.output_size()
        },
    );
}

#[test]
fn prop_winograd_polyphase_5x5_stride2_matches_naive() {
    // the polyphase path proper: 5×5 taps at stride 2 decimate into four
    // unit-stride residues; odd outputs leave ragged 2×2 scatter tiles
    forall(
        Config { cases: 16, seed: 82 },
        |r| {
            let s = ConvShape::new(
                r.range(1, 3),
                r.range(1, 5),
                r.range(1, 5),
                r.range(2, 9),
                r.range(2, 9),
                5,
                5,
                2,
                2,
            );
            (s, r.range(0, 1_000_000))
        },
        |(s, seed)| {
            let (x, w) = paper_operands(s, *seed);
            let plan = WinoPlan::new(s, Precision::uniform(), 4096.0);
            let counters = TrafficCounters::new();
            let got = conv_winograd_counted(&x, &w, &plan, &counters);
            let want = conv7nl_naive(&x, &w, s);
            plan.sub_convs() >= 4
                && got.max_abs_diff(&want) <= winograd_tolerance(&x, &w, s)
                && got.rel_l2(&want) < 1e-4
                && counters.snapshot() == expected_winograd_traffic(&plan)
        },
    );
}

#[test]
fn prop_winograd_parallel_and_blocking_deterministic() {
    // tile-block size shapes residency only: a tight-budget plan, a loose
    // one, and the pool-parallel sweep all agree bitwise with identical
    // (blocking-independent) traffic
    forall(
        Config { cases: 12, seed: 83 },
        |r| (random_tiled_shape(r), r.range(0, 1_000_000)),
        |(s, seed)| {
            let (x, w) = paper_operands(s, *seed);
            let tight = WinoPlan::new(s, Precision::uniform(), 512.0);
            let loose =
                WinoPlan::new(s, Precision::uniform(), (1u64 << 20) as f64);
            let (ct, cl) = (TrafficCounters::new(), TrafficCounters::new());
            let a = conv_winograd_counted(&x, &w, &tight, &ct);
            let b = conv_winograd_counted(&x, &w, &loose, &cl);
            let (xa, wa, pa) =
                (Arc::new(x), Arc::new(w), Arc::new(loose.clone()));
            let pool = ThreadPool::new(3);
            let cp = Arc::new(TrafficCounters::new());
            let c = conv_winograd_parallel(&xa, &wa, &pa, &pool, &cp);
            a.max_abs_diff(&b) == 0.0
                && a.max_abs_diff(&c) == 0.0
                && ct.snapshot() == cl.snapshot()
                && cp.snapshot() == expected_winograd_traffic(&loose)
        },
    );
}

// ---------------- backward passes (dFilter / dInput) ----------------

#[test]
fn prop_tiled_backward_passes_bitwise_match_oracles() {
    // the backward accumulation-order contract: tiled dFilter/dInput are
    // bitwise identical to the conv/training.rs naive oracles for any
    // shape (strided, non-square, ragged), any memory budget, and any
    // (mixed) precision the plan is solved under — and the measured word
    // traffic equals the per-pass analytic tile-grid model exactly
    forall(
        Config { cases: 18, seed: 91 },
        |r| {
            (
                random_tiled_shape(r),
                random_precision(r),
                (1u64 << r.range(9, 14)) as f64,
                r.range(0, 1_000_000),
            )
        },
        |(s, p, m, seed)| {
            [ConvPass::DFilter, ConvPass::DInput].iter().all(|&pass| {
                let plan = TilePlan::for_pass(pass, s, *p, *m);
                let (a, b) = pass_operands(pass, s, *seed);
                let counters = TrafficCounters::new();
                let got = conv_pass_tiled_counted(pass, &a, &b, &plan, &counters);
                let want = pass.naive_oracle(&a, &b, s);
                got.dims == want.dims
                    && got.max_abs_diff(&want) == 0.0
                    && counters.snapshot() == expected_pass_traffic(&plan)
            })
        },
    );
}

#[test]
fn prop_backward_parallel_bitwise_matches_serial() {
    let pool = ThreadPool::new(4);
    forall(
        Config { cases: 8, seed: 92 },
        |r| (random_tiled_shape(r), (1u64 << r.range(9, 13)) as f64),
        |(s, m)| {
            [ConvPass::DFilter, ConvPass::DInput].iter().all(|&pass| {
                let plan =
                    Arc::new(TilePlan::for_pass(pass, s, Precision::uniform(), *m));
                let (a, b) = pass_operands(pass, s, 13);
                let (a, b) = (Arc::new(a), Arc::new(b));
                let serial = conv_pass_tiled(pass, &a, &b, &plan);
                let ctr = Arc::new(TrafficCounters::new());
                let par =
                    conv_pass_tiled_parallel(pass, &a, &b, &plan, &pool, &ctr);
                par.max_abs_diff(&serial) == 0.0
                    && ctr.snapshot() == expected_pass_traffic(&plan)
            })
        },
    );
}

#[test]
fn tiled_backward_passes_bitwise_match_oracles_on_full_catalog() {
    // every catalog layer (runnable-size variant), both gradient passes:
    // bitwise vs the naive oracles and exact counter/model agreement —
    // the acceptance gate of the pass-generic engine
    let p = Precision::uniform();
    let m = 65536.0;
    for l in resnet50_layers(2).into_iter().chain(alexnet_layers(2)) {
        let s = scaled(l.shape, 4);
        for pass in [ConvPass::DFilter, ConvPass::DInput] {
            let plan = TilePlan::for_pass(pass, &s, p, m);
            let (a, b) = pass_operands(pass, &s, 103);
            let counters = TrafficCounters::new();
            let got = conv_pass_tiled_counted(pass, &a, &b, &plan, &counters);
            let want = pass.naive_oracle(&a, &b, &s);
            assert_eq!(
                got.max_abs_diff(&want),
                0.0,
                "{} {}: tiled gradient diverged from the oracle",
                l.name,
                pass.name()
            );
            assert_eq!(
                counters.snapshot(),
                expected_pass_traffic(&plan),
                "{} {}",
                l.name,
                pass.name()
            );
        }
    }
}

#[test]
fn degenerate_backward_shapes_return_empty_or_zero_gradients() {
    let p = Precision::uniform();
    // zero batch: dFilter is the full-size all-zero gradient (like the
    // oracle), dInput is empty on the batch axis
    let s = ConvShape::new(0, 3, 4, 5, 5, 3, 3, 1, 1);
    for pass in [ConvPass::DFilter, ConvPass::DInput] {
        let plan = TilePlan::for_pass(pass, &s, p, 1024.0);
        let (a, b) = pass_operands(pass, &s, 1);
        let got = conv_pass_tiled(pass, &a, &b, &plan);
        let want = pass.naive_oracle(&a, &b, &s);
        assert_eq!(got.dims, want.dims, "{}", pass.name());
        assert!(got.data.iter().all(|&v| v == 0.0), "{}", pass.name());
        assert_eq!(expected_pass_traffic(&plan), Traffic::default());
    }
    // zero input channels: dFilter empty, dInput full-size zero
    let s2 = ConvShape::new(2, 0, 4, 5, 5, 3, 3, 1, 1);
    for pass in [ConvPass::DFilter, ConvPass::DInput] {
        let plan = TilePlan::for_pass(pass, &s2, p, 1024.0);
        let (a, b) = pass_operands(pass, &s2, 2);
        let got = conv_pass_tiled(pass, &a, &b, &plan);
        let want = pass.naive_oracle(&a, &b, &s2);
        assert_eq!(got.dims, want.dims, "{}", pass.name());
        assert!(got.data.iter().all(|&v| v == 0.0), "{}", pass.name());
    }
}

// ---------------- fused network pipelines ----------------

/// Random 2–4 stage chains satisfying the paper's chaining convention
/// `σ·wO + wF = previous wO` per axis: strided, non-square, ragged. The
/// head stage is sized so at least one extension always exists.
fn random_chain(r: &mut Rng) -> NetworkSpec {
    let head = ConvShape::new(
        r.range(1, 3),
        r.range(1, 4),
        r.range(1, 5),
        r.range(6, 14),
        r.range(6, 14),
        r.range(1, 3),
        r.range(1, 3),
        1,
        1,
    );
    let mut shapes = vec![head];
    let want = r.range(2, 4) as usize;
    while shapes.len() < want {
        let prev = *shapes.last().unwrap();
        let pick = |r: &mut Rng, extent: u64| -> Option<(u64, u64, u64)> {
            // candidates (σ, f, out) with σ ≤ f, out ≥ 1, σ·out + f = extent
            let mut cands = Vec::new();
            for s in 1..=2u64 {
                for f in s..=(s + 3) {
                    if extent > f && (extent - f) % s == 0 {
                        cands.push((s, f, (extent - f) / s));
                    }
                }
            }
            if cands.is_empty() {
                None
            } else {
                Some(*r.choose(&cands))
            }
        };
        let (Some((sw, wf, wo)), Some((sh, hf, ho))) =
            (pick(r, prev.w_o), pick(r, prev.h_o))
        else {
            break;
        };
        shapes.push(ConvShape::new(
            prev.n,
            prev.c_o,
            r.range(1, 5),
            wo,
            ho,
            wf,
            hf,
            sw,
            sh,
        ));
    }
    if shapes.len() < 2 {
        // head extents ≥ 6 always admit (σ=1, f=1, out=extent−1)
        unreachable!("chain generator must produce at least two stages");
    }
    NetworkSpec::uniform("prop", &shapes).expect("generated chain is valid")
}

fn chain_filters(net: &NetworkSpec, seed: u64) -> Vec<Tensor4> {
    net.stages
        .iter()
        .enumerate()
        .map(|(i, st)| Tensor4::randn(st.shape.filter_dims(), seed + 1 + i as u64))
        .collect()
}

/// Words crossing fused boundaries must be zero (one shared definition:
/// [`FusePlan::boundary_words`]).
fn fused_boundaries_silent(plan: &FusePlan, measured: &[convbound::kernels::Traffic]) -> bool {
    plan.boundary_words(measured) == 0
}

#[test]
fn prop_fully_fused_network_bitwise_matches_staged_oracle() {
    // with every boundary fused, the network executor performs exactly the
    // oracle's per-element operations (in order), tile by tile — so the
    // output is bitwise identical, for arbitrary (ragged) tile choices,
    // and no words cross any inter-stage boundary
    forall(
        Config { cases: 14, seed: 81 },
        |r| {
            let net = random_chain(r);
            let last = net.stages.last().unwrap().shape;
            let tile = (
                r.range(1, last.n),
                r.range(1, last.w_o),
                r.range(1, last.h_o),
            );
            (net, tile, r.range(0, 1_000_000))
        },
        |(net, (b_n, b_wo, b_ho), seed)| {
            let cache = TilePlanCache::new();
            // force one end-to-end fused group with the random tile: the
            // executor's correctness must not depend on the planner's
            // footprint rule
            let mut plan = FusePlan::new(&net.stages, 65536.0, &cache);
            plan.groups = vec![convbound::kernels::FuseGroup {
                start: 0,
                end: net.stages.len() - 1,
                b_n: *b_n,
                b_wo: *b_wo,
                b_ho: *b_ho,
            }];
            let image = Tensor4::randn(net.input_dims(), *seed);
            let filters = chain_filters(net, *seed);
            let frefs: Vec<&Tensor4> = filters.iter().collect();
            let counters = NetTrafficCounters::new(net.stages.len());
            let got = conv_network_fused_counted(&image, &frefs, &plan, &counters);
            let want = naive_network(&image, &frefs, &net.stages);
            let measured = counters.snapshot();
            got.max_abs_diff(&want) == 0.0
                && measured == plan.expected_network_traffic()
                && fused_boundaries_silent(&plan, &measured)
        },
    );
}

#[test]
fn prop_planned_network_matches_oracle_with_exact_traffic() {
    // the planner's own grouping (random memory budgets force mixed
    // fuse/materialize decisions): numerics agree with the staged oracle
    // (bitwise when the plan fused end to end, else within tolerance —
    // materialized stages run the LP-tiled engine's accumulation order),
    // measured per-stage traffic equals the analytic model exactly, and
    // fused boundaries move zero words
    forall(
        Config { cases: 14, seed: 84 },
        |r| (random_chain(r), (1u64 << r.range(9, 14)) as f64, r.range(0, 1_000_000)),
        |(net, m, seed)| {
            let cache = TilePlanCache::new();
            let plan = FusePlan::new(&net.stages, *m, &cache);
            let image = Tensor4::randn(net.input_dims(), *seed);
            let filters = chain_filters(net, *seed);
            let frefs: Vec<&Tensor4> = filters.iter().collect();
            let counters = NetTrafficCounters::new(net.stages.len());
            let got = conv_network_fused_counted(&image, &frefs, &plan, &counters);
            let want = naive_network(&image, &frefs, &net.stages);
            let fully_fused =
                plan.groups.len() == 1 && plan.groups[0].is_fused();
            let numerics_ok = if fully_fused {
                got.max_abs_diff(&want) == 0.0
            } else {
                got.rel_l2(&want) < 1e-4
            };
            let measured = counters.snapshot();
            numerics_ok
                && measured == plan.expected_network_traffic()
                && fused_boundaries_silent(&plan, &measured)
        },
    );
}

#[test]
fn prop_packed_fused_bitwise_matches_reference_and_oracle() {
    // the packed microkernel path performs the reference nest's exact
    // per-element accumulation order (one full reduction tile per stage),
    // so packed, reference and the staged oracle agree bitwise — for
    // arbitrary ragged tiles on strided, non-square chains — and both
    // fused paths charge identical traffic and halo words
    forall(
        Config { cases: 10, seed: 85 },
        |r| {
            let net = random_chain(r);
            let last = net.stages.last().unwrap().shape;
            let tile = (
                r.range(1, last.n),
                r.range(1, last.w_o),
                r.range(1, last.h_o),
            );
            (net, tile, r.range(0, 1_000_000))
        },
        |(net, (b_n, b_wo, b_ho), seed)| {
            let cache = TilePlanCache::new();
            let mut packed = FusePlan::new(&net.stages, 65536.0, &cache);
            packed.groups = vec![convbound::kernels::FuseGroup {
                start: 0,
                end: net.stages.len() - 1,
                b_n: *b_n,
                b_wo: *b_wo,
                b_ho: *b_ho,
            }];
            let mut reference = packed.clone();
            reference.exec = FusedExec::Reference;
            let image = Tensor4::randn(net.input_dims(), *seed);
            let filters = chain_filters(net, *seed);
            let frefs: Vec<&Tensor4> = filters.iter().collect();
            let pc = NetTrafficCounters::new(net.stages.len());
            let rc = NetTrafficCounters::new(net.stages.len());
            let p = conv_network_fused_counted(&image, &frefs, &packed, &pc);
            let q = conv_network_fused_counted(&image, &frefs, &reference, &rc);
            let want = naive_network(&image, &frefs, &net.stages);
            p.max_abs_diff(&q) == 0.0
                && p.max_abs_diff(&want) == 0.0
                && pc.snapshot() == rc.snapshot()
                && pc.halo_snapshot() == rc.halo_snapshot()
        },
    );
}

#[test]
fn prop_halo_cache_bitwise_with_exact_adjusted_traffic() {
    // the sliding-window halo cache never changes a bit of the output
    // (cached rows are bitwise equal to what recompute would produce);
    // measured traffic equals the cache-adjusted analytic model exactly,
    // measured halo words equal the analytic savings model exactly, and
    // caching can only reduce total traffic
    forall(
        Config { cases: 10, seed: 86 },
        |r| {
            let net = random_chain(r);
            let last = net.stages.last().unwrap().shape;
            // small h-blocks force multi-tile sweeps where the cache works
            let tile = (
                r.range(1, last.n),
                r.range(1, last.w_o),
                r.range(1, (last.h_o / 2).max(1)),
            );
            (net, tile, r.range(0, 1_000_000))
        },
        |(net, (b_n, b_wo, b_ho), seed)| {
            let cache = TilePlanCache::new();
            let mut on = FusePlan::new(&net.stages, 65536.0, &cache);
            on.groups = vec![convbound::kernels::FuseGroup {
                start: 0,
                end: net.stages.len() - 1,
                b_n: *b_n,
                b_wo: *b_wo,
                b_ho: *b_ho,
            }];
            on.halo_cache = true;
            let mut off = on.clone();
            off.halo_cache = false;
            let image = Tensor4::randn(net.input_dims(), *seed);
            let filters = chain_filters(net, *seed);
            let frefs: Vec<&Tensor4> = filters.iter().collect();
            let c_on = NetTrafficCounters::new(net.stages.len());
            let c_off = NetTrafficCounters::new(net.stages.len());
            let a = conv_network_fused_counted(&image, &frefs, &on, &c_on);
            let b = conv_network_fused_counted(&image, &frefs, &off, &c_off);
            a.max_abs_diff(&b) == 0.0
                && c_on.snapshot() == on.expected_network_traffic()
                && c_off.snapshot() == off.expected_network_traffic()
                && c_on.halo_snapshot() == on.expected_halo_words()
                && c_off.halo_snapshot().iter().all(|&w| w == 0)
                && Traffic::sum(&c_on.snapshot()).total()
                    <= Traffic::sum(&c_off.snapshot()).total()
        },
    );
}

#[test]
fn prop_fused_parallel_bitwise_matches_serial() {
    let pool = ThreadPool::new(4);
    forall(
        Config { cases: 8, seed: 82 },
        |r| (random_chain(r), (1u64 << r.range(9, 13)) as f64),
        |(net, m)| {
            let cache = TilePlanCache::new();
            let plan = Arc::new(FusePlan::new(&net.stages, *m, &cache));
            let image = Arc::new(Tensor4::randn(net.input_dims(), 3));
            let filters: Vec<Arc<Tensor4>> =
                chain_filters(net, 3).into_iter().map(Arc::new).collect();
            let frefs: Vec<&Tensor4> =
                filters.iter().map(|a| a.as_ref()).collect();
            let serial_ctr = NetTrafficCounters::new(net.stages.len());
            let serial =
                conv_network_fused_counted(&image, &frefs, &plan, &serial_ctr);
            let par_ctr = NetTrafficCounters::new(net.stages.len());
            let par =
                conv_network_fused(&image, &filters, &plan, &pool, &par_ctr);
            par.max_abs_diff(&serial) == 0.0
                && par_ctr.snapshot() == serial_ctr.snapshot()
        },
    );
}

// ---------------- fused training sweeps (backward / step) ----------------

/// Re-precision a generated chain: same shapes, independently random
/// per-stage precisions — the planner's LP solves and the traffic model
/// must hold for mixed-precision chains too (numerics are unaffected; the
/// data stays f32).
fn mixed_precision_stages(net: &NetworkSpec, r: &mut Rng) -> Vec<NetworkStage> {
    net.stages
        .iter()
        .map(|st| NetworkStage { shape: st.shape, precision: random_precision(r) })
        .collect()
}

fn stage_filters(stages: &[NetworkStage], seed: u64) -> Vec<Tensor4> {
    stages
        .iter()
        .enumerate()
        .map(|(i, st)| Tensor4::randn(st.shape.filter_dims(), seed + 1 + i as u64))
        .collect()
}

fn tail_gradient(stages: &[NetworkStage], seed: u64) -> Tensor4 {
    let s = &stages[stages.len() - 1].shape;
    Tensor4::randn(
        [s.n as usize, s.c_o as usize, s.w_o as usize, s.h_o as usize],
        seed,
    )
}

#[test]
fn prop_fused_backward_bitwise_matches_chained_oracle() {
    // the backward accumulation-order contract extends to whole networks:
    // ANY backward plan — fused, mixed or materialized, any memory budget,
    // any (mixed) precision it was solved under — reproduces the chained
    // dInput oracle bitwise; measured per-stage traffic equals the
    // analytic model exactly and fused boundaries move zero words
    forall(
        Config { cases: 12, seed: 87 },
        |r| {
            let net = random_chain(r);
            let stages = mixed_precision_stages(&net, r);
            (stages, (1u64 << r.range(9, 14)) as f64, r.range(0, 1_000_000))
        },
        |(stages, m, seed)| {
            let cache = TilePlanCache::new();
            let plan = FusePlan::for_pass(NetPass::Backward, stages, *m, &cache);
            let gout = tail_gradient(stages, *seed);
            let filters = stage_filters(stages, *seed);
            let frefs: Vec<&Tensor4> = filters.iter().collect();
            let counters = NetTrafficCounters::new(stages.len());
            let got = conv_network_bwd_counted(&gout, &frefs, &plan, &counters);
            let want = naive_network_bwd(&gout, &frefs, stages);
            let measured = counters.snapshot();
            got.max_abs_diff(&want) == 0.0
                && measured == plan.expected_network_traffic()
                && fused_boundaries_silent(&plan, &measured)
        },
    );
}

#[test]
fn prop_fused_backward_parallel_bitwise_matches_serial() {
    let pool = ThreadPool::new(4);
    forall(
        Config { cases: 8, seed: 88 },
        |r| (random_chain(r), (1u64 << r.range(9, 13)) as f64),
        |(net, m)| {
            let cache = TilePlanCache::new();
            let plan = Arc::new(FusePlan::for_pass(
                NetPass::Backward,
                &net.stages,
                *m,
                &cache,
            ));
            let gout = Arc::new(tail_gradient(&net.stages, 5));
            let filters: Vec<Arc<Tensor4>> =
                stage_filters(&net.stages, 5).into_iter().map(Arc::new).collect();
            let frefs: Vec<&Tensor4> =
                filters.iter().map(|a| a.as_ref()).collect();
            let serial_ctr = NetTrafficCounters::new(net.stages.len());
            let serial =
                conv_network_bwd_counted(&gout, &frefs, &plan, &serial_ctr);
            let par_ctr = NetTrafficCounters::new(net.stages.len());
            let par = conv_network_bwd(&gout, &filters, &plan, &pool, &par_ctr);
            par.max_abs_diff(&serial) == 0.0
                && par_ctr.snapshot() == serial_ctr.snapshot()
        },
    );
}

#[test]
fn prop_backward_halo_on_off_bitwise_with_exact_traffic() {
    // the transposed-stencil halo cache of the backward sweep: toggling it
    // on an otherwise identical plan never changes a bit of the image
    // gradient; measured traffic equals each variant's analytic model
    // exactly, measured halo words equal the savings model (and are all
    // zero with the cache off), and caching can only reduce total traffic
    forall(
        Config { cases: 10, seed: 89 },
        |r| (random_chain(r), r.range(0, 1_000_000)),
        |(net, seed)| {
            let cache = TilePlanCache::new();
            let on = FusePlan::for_pass(
                NetPass::Backward,
                &net.stages,
                65536.0,
                &cache,
            );
            let mut off = on.clone();
            off.halo_cache = false;
            let gout = tail_gradient(&net.stages, *seed);
            let filters = stage_filters(&net.stages, *seed);
            let frefs: Vec<&Tensor4> = filters.iter().collect();
            let c_on = NetTrafficCounters::new(net.stages.len());
            let c_off = NetTrafficCounters::new(net.stages.len());
            let a = conv_network_bwd_counted(&gout, &frefs, &on, &c_on);
            let b = conv_network_bwd_counted(&gout, &frefs, &off, &c_off);
            a.max_abs_diff(&b) == 0.0
                && c_on.snapshot() == on.expected_network_traffic()
                && c_off.snapshot() == off.expected_network_traffic()
                && c_on.halo_snapshot() == on.expected_halo_words()
                && c_off.halo_snapshot().iter().all(|&w| w == 0)
                && Traffic::sum(&c_on.snapshot()).total()
                    <= Traffic::sum(&c_off.snapshot()).total()
        },
    );
}

#[test]
fn prop_fused_step_matches_sgd_oracle() {
    // the tentpole invariant: a whole training step as fused sweeps. When
    // every non-last group is fused ([`FusePlan::step_bitwise`]) the
    // step's filter and image gradients reproduce the layer-by-layer SGD
    // oracle bitwise; otherwise (materialized activations re-enter through
    // the tiled engine's accumulation order) within tolerance. Measured
    // per-stage traffic equals the analytic model exactly and fused
    // boundaries move zero words — mixed precisions, random budgets
    forall(
        Config { cases: 10, seed: 90 },
        |r| {
            let net = random_chain(r);
            let stages = mixed_precision_stages(&net, r);
            (stages, (1u64 << r.range(10, 15)) as f64, r.range(0, 1_000_000))
        },
        |(stages, m, seed)| {
            let cache = TilePlanCache::new();
            let plan = FusePlan::for_pass(NetPass::Step, stages, *m, &cache);
            let head = &stages[0].shape;
            let image = Tensor4::randn(
                [
                    head.n as usize,
                    head.c_i as usize,
                    head.in_w() as usize,
                    head.in_h() as usize,
                ],
                seed + 100,
            );
            let gout = tail_gradient(stages, *seed);
            let filters = stage_filters(stages, *seed);
            let frefs: Vec<&Tensor4> = filters.iter().collect();
            let counters = NetTrafficCounters::new(stages.len());
            let (dw, din) =
                conv_network_step_counted(&image, &frefs, &gout, &plan, &counters);
            let (dw_ref, din_ref) =
                naive_network_step(&image, &frefs, &gout, stages);
            let numerics_ok = if plan.step_bitwise() {
                din.max_abs_diff(&din_ref) == 0.0
                    && dw
                        .iter()
                        .zip(&dw_ref)
                        .all(|(a, b)| a.max_abs_diff(b) == 0.0)
            } else {
                din.rel_l2(&din_ref) < 1e-4
                    && dw.iter().zip(&dw_ref).all(|(a, b)| a.rel_l2(b) < 1e-4)
            };
            let measured = counters.snapshot();
            numerics_ok
                && measured == plan.expected_network_traffic()
                && fused_boundaries_silent(&plan, &measured)
        },
    );
}

#[test]
fn degenerate_network_sweeps_return_zero_gradients() {
    let p = Precision::uniform();
    let cache = TilePlanCache::new();
    // two degenerate chains NetworkSpec would reject (zero updates), built
    // as raw stages: a zero-batch chain, and a chain whose interior
    // boundary carries zero channels. Both backward and step sweeps must
    // agree with the oracles' dims and zero values without panicking.
    let chains: [Vec<ConvShape>; 2] = [
        vec![
            ConvShape::new(0, 3, 4, 8, 8, 3, 3, 1, 1),
            ConvShape::new(0, 4, 2, 6, 6, 2, 2, 1, 1),
        ],
        vec![
            ConvShape::new(2, 3, 0, 8, 8, 3, 3, 1, 1),
            ConvShape::new(2, 0, 2, 6, 6, 2, 2, 1, 1),
        ],
    ];
    for shapes in &chains {
        let stages: Vec<NetworkStage> = shapes
            .iter()
            .map(|s| NetworkStage { shape: *s, precision: p })
            .collect();
        let gout = tail_gradient(&stages, 3);
        let filters = stage_filters(&stages, 3);
        let frefs: Vec<&Tensor4> = filters.iter().collect();

        let bwd = FusePlan::for_pass(NetPass::Backward, &stages, 4096.0, &cache);
        let counters = NetTrafficCounters::new(stages.len());
        let got = conv_network_bwd_counted(&gout, &frefs, &bwd, &counters);
        let want = naive_network_bwd(&gout, &frefs, &stages);
        assert_eq!(got.dims, want.dims);
        assert!(got.data.iter().all(|&v| v == 0.0), "bwd zero gradient");
        assert_eq!(counters.snapshot(), bwd.expected_network_traffic());

        let head = &stages[0].shape;
        let image = Tensor4::randn(
            [
                head.n as usize,
                head.c_i as usize,
                head.in_w() as usize,
                head.in_h() as usize,
            ],
            4,
        );
        let step = FusePlan::for_pass(NetPass::Step, &stages, 4096.0, &cache);
        let counters = NetTrafficCounters::new(stages.len());
        let (dw, din) =
            conv_network_step_counted(&image, &frefs, &gout, &step, &counters);
        let (dw_ref, din_ref) = naive_network_step(&image, &frefs, &gout, &stages);
        assert_eq!(din.dims, din_ref.dims);
        assert!(din.data.iter().all(|&v| v == 0.0), "step zero dImage");
        for (k, (a, b)) in dw.iter().zip(&dw_ref).enumerate() {
            assert_eq!(a.dims, b.dims, "stage {k}");
            assert!(a.data.iter().all(|&v| v == 0.0), "stage {k} zero dFilter");
        }
        assert_eq!(counters.snapshot(), step.expected_network_traffic());
    }
}

#[test]
fn prop_axpy_unrolled_bitwise_matches_scalar() {
    forall(
        Config { cases: 120, seed: 83 },
        |r| (r.range(0, 40) as usize, r.range(0, 1_000_000)),
        |(len, seed)| {
            let mut rng = Rng::new(*seed);
            let f_row = rng.normal_vec(*len);
            let base = rng.normal_vec(*len);
            let x = rng.normal_vec(1)[0];
            let mut a = base.clone();
            let mut b = base;
            axpy(&mut a, &f_row, x);
            axpy_scalar(&mut b, &f_row, x);
            a.iter().zip(&b).all(|(va, vb)| va.to_bits() == vb.to_bits())
        },
    );
}

// ---------------- naive conv oracle ----------------

#[test]
fn prop_conv_linear_in_input() {
    // conv(a·x, w) = a·conv(x, w)
    forall(
        Config { cases: 20, seed: 61 },
        |r| {
            let s = ConvShape::new(
                r.range(1, 3), r.range(1, 4), r.range(1, 4),
                r.range(2, 6), r.range(2, 6), r.range(1, 3), r.range(1, 3), 1, 1,
            );
            (s, r.range(0, 1000))
        },
        |(s, seed)| {
            let x = Tensor4::randn(
                [s.n as usize, s.c_i as usize, s.in_w() as usize, s.in_h() as usize],
                *seed,
            );
            let w = Tensor4::randn(
                [s.c_i as usize, s.c_o as usize, s.w_f as usize, s.h_f as usize],
                seed + 1,
            );
            let mut x2 = x.clone();
            for v in x2.data.iter_mut() {
                *v *= 2.0;
            }
            let a = conv7nl_naive(&x, &w, s);
            let b = conv7nl_naive(&x2, &w, s);
            let mut a2 = a.clone();
            for v in a2.data.iter_mut() {
                *v *= 2.0;
            }
            a2.max_abs_diff(&b) < 1e-3
        },
    );
}
