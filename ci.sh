#!/usr/bin/env bash
# CI for convbound: offline build + tests, style gates when the toolchain
# components are installed, and a pjrt feature compile-check when the
# external xla crate is wired into Cargo.toml.
set -euo pipefail
cd "$(dirname "$0")"

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> cargo run --release --example quickstart"
cargo run --release --example quickstart >/dev/null

echo "==> cargo run --release -- exec --network tiny_resnet --check"
cargo run --release -- exec --network tiny_resnet --check >/dev/null

echo "==> cargo bench --bench e2e_runtime -- --smoke  (writes BENCH_kernels.json + BENCH_network.json)"
rm -f BENCH_kernels.json BENCH_network.json  # stale files must not mask a failed write
cargo bench --bench e2e_runtime -- --smoke >/dev/null
test -s BENCH_kernels.json || { echo "FAIL: BENCH_kernels.json missing"; exit 1; }
test -s BENCH_network.json || { echo "FAIL: BENCH_network.json missing"; exit 1; }

if cargo fmt --version >/dev/null 2>&1; then
    echo "==> cargo fmt --check"
    cargo fmt --check
else
    echo "SKIP: rustfmt not installed"
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "==> cargo clippy --all-targets -- -D warnings"
    cargo clippy --all-targets -- -D warnings
else
    echo "SKIP: clippy not installed"
fi

# The pjrt backend needs the external `xla` crate; the offline image does
# not ship it. Compile-check the feature only when a dependency line is
# present (see the [features] comment in Cargo.toml).
if grep -Eq '^\s*xla\s*=' Cargo.toml; then
    echo "==> cargo check --features pjrt"
    cargo check --features pjrt
else
    echo "SKIP: pjrt feature check (xla crate not wired into Cargo.toml)"
fi

echo "CI OK"
