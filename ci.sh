#!/usr/bin/env bash
# CI for convbound: offline build + tests, style gates when the toolchain
# components are installed, and a pjrt feature compile-check when the
# external xla crate is wired into Cargo.toml.
set -euo pipefail
cd "$(dirname "$0")"

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> cargo run --release --example quickstart"
cargo run --release --example quickstart >/dev/null

echo "==> cargo run --release -- exec --network tiny_resnet --check"
cargo run --release -- exec --network tiny_resnet --check >/dev/null

echo "==> cargo run --release -- exec --network deep_mixnet --check  (mixed fused/materialized plan)"
cargo run --release -- exec --network deep_mixnet --check >/dev/null

echo "==> cargo run --release -- exec --pass dfilter --check  (tiled filter gradient, bitwise vs oracle)"
cargo run --release -- exec --layer conv4_x --scale 4 --pass dfilter --check >/dev/null

echo "==> cargo run --release -- exec --pass dinput --check  (tiled input gradient, bitwise vs oracle)"
cargo run --release -- exec --layer conv4_x --scale 4 --pass dinput --check >/dev/null

echo "==> cargo run --release -- exec --kernel winograd --check  (tiled F(2,3), tolerance oracle + exact traffic)"
cargo run --release -- exec --layer conv4_x --scale 4 --kernel winograd --check >/dev/null

echo "==> cargo run --release -- exec --network tiny_resnet --pass bwd --check  (fused backward sweep, bitwise vs chained oracle)"
cargo run --release -- exec --network tiny_resnet --pass bwd --check >/dev/null

echo "==> cargo run --release -- exec --network tiny_resnet --pass step --check --trace  (fused training step + JSONL event log)"
rm -f /tmp/convbound_ci_trace.jsonl
cargo run --release -- exec --network tiny_resnet --pass step --check \
    --trace /tmp/convbound_ci_trace.jsonl >/dev/null
test -s /tmp/convbound_ci_trace.jsonl \
    || { echo "FAIL: --trace wrote no events"; exit 1; }

echo "==> trace check: every line parses, spans balance"
cargo run --release -- trace check /tmp/convbound_ci_trace.jsonl

echo "==> trace summarize: zero measured-vs-expected traffic mismatches"
cargo run --release -- trace summarize /tmp/convbound_ci_trace.jsonl \
    | tee /tmp/convbound_ci_trace_summary.txt
grep -q "measured-vs-expected mismatches: 0" /tmp/convbound_ci_trace_summary.txt \
    || { echo "FAIL: traced run logged traffic that disagrees with the analytic model"; exit 1; }

echo "==> exec --network tiny_resnet --check --faults exec:panic:every=3  (injected tile panics degrade to the layered oracle, bitwise)"
rm -f /tmp/convbound_ci_faults.jsonl
cargo run --release -- exec --network tiny_resnet --check \
    --faults exec:panic:every=3 --trace /tmp/convbound_ci_faults.jsonl \
    | tee /tmp/convbound_ci_faults_out.txt
grep -q "DEGRADED" /tmp/convbound_ci_faults_out.txt \
    || { echo "FAIL: injected panics did not trigger the fallback path"; exit 1; }

echo "==> trace check: the faulted run's spans still balance with terminal dispositions"
cargo run --release -- trace check /tmp/convbound_ci_faults.jsonl

echo "==> trace summarize: the faulted run's panics and degradations are in the log"
cargo run --release -- trace summarize /tmp/convbound_ci_faults.jsonl \
    | tee /tmp/convbound_ci_faults_summary.txt
grep -Eq "faults: shed=0 expired=0 panicked=[1-9]" /tmp/convbound_ci_faults_summary.txt \
    || { echo "FAIL: trace replay saw no caught panics despite exec:panic:every=3"; exit 1; }
grep -Eq "degraded=[1-9]" /tmp/convbound_ci_faults_summary.txt \
    || { echo "FAIL: trace replay saw no degradations despite exec:panic:every=3"; exit 1; }

echo "==> serve --queue 4 --policy shed under a stalled backend: bounded depth + exact trace replay"
rm -f /tmp/convbound_ci_serve_faults.jsonl
cargo run --release -- serve --requests 48 --queue 4 --policy shed \
    --faults "queue:stall:ms=25" --trace /tmp/convbound_ci_serve_faults.jsonl --check \
    | tee /tmp/convbound_ci_serve_out.txt
grep -q "trace replay matches ServerStats exactly: OK" /tmp/convbound_ci_serve_out.txt \
    || { echo "FAIL: serve --check did not verify the trace replay"; exit 1; }
cargo run --release -- trace check /tmp/convbound_ci_serve_faults.jsonl

echo "==> serve a whole network with injected panics: every request still answered"
cargo run --release -- serve --requests 16 --key tiny_resnet/network \
    --faults exec:panic:every=5 --check >/dev/null

echo "==> exec --network tiny_resnet --shards 4 --shard-by auto --check  (sharded engine: bitwise vs staged + exact exchange)"
cargo run --release -- exec --network tiny_resnet --shards 4 --shard-by auto --check >/dev/null

echo "==> exec --layer conv4_x --shards 2 --faults exec:panic:every=1 --check  (a panicking shard degrades, output still bitwise)"
cargo run --release -- exec --layer conv4_x --scale 4 --shards 2 --shard-by batch \
    --faults exec:panic:every=1 --check \
    | tee /tmp/convbound_ci_shard_faults_out.txt
grep -q "DEGRADED" /tmp/convbound_ci_shard_faults_out.txt \
    || { echo "FAIL: injected shard panics did not trigger the degraded path"; exit 1; }

echo "==> serve a whole network through the sharded executor: every request still answered"
cargo run --release -- serve --requests 16 --key tiny_resnet/network \
    --shards 4 --shard-by auto --check >/dev/null

echo "==> cargo bench --bench e2e_runtime -- --smoke  (writes BENCH_kernels.json + BENCH_network.json + BENCH_training.json + BENCH_parallel.json)"
rm -f BENCH_kernels.json BENCH_network.json BENCH_training.json BENCH_parallel.json  # stale files must not mask a failed write
cargo bench --bench e2e_runtime -- --smoke >/dev/null
test -s BENCH_kernels.json || { echo "FAIL: BENCH_kernels.json missing"; exit 1; }
test -s BENCH_network.json || { echo "FAIL: BENCH_network.json missing"; exit 1; }
test -s BENCH_training.json || { echo "FAIL: BENCH_training.json missing"; exit 1; }
test -s BENCH_parallel.json || { echo "FAIL: BENCH_parallel.json missing"; exit 1; }

echo "==> BENCH_kernels.json: tracing overhead within budget"
# the traced-vs-untraced pair runs INSIDE the bench; here we gate on the
# flag it computed (p50 ratio within the slack)
grep -q '"trace_overhead_ok":true' BENCH_kernels.json \
    || { echo "FAIL: JSONL tracing slowed the tiled hot path beyond the budget"; exit 1; }

echo "==> BENCH_kernels.json: winograd variant swept with measured traffic"
# the winograd tolerance + exact-traffic gates run INSIDE the bench (a
# violation panics it); here we assert the variant actually appears with
# a nonzero measured word count
grep -q '"kernel":"winograd"' BENCH_kernels.json \
    || { echo "FAIL: winograd entries missing from BENCH_kernels.json"; exit 1; }
grep -Eq '"kernel":"winograd","measured_words":[1-9]' BENCH_kernels.json \
    || { echo "FAIL: winograd rows carry no measured traffic"; exit 1; }

echo "==> BENCH_training.json: per-pass entries present"
# the bitwise tiled-vs-oracle gate lives INSIDE the bench (training_sweep
# asserts before timing): a violation panics the bench and the `test -s`
# above fails on the missing file. Here we only assert both passes were
# actually swept.
grep -q '"pass":"dfilter"' BENCH_training.json \
    || { echo "FAIL: dfilter entries missing from BENCH_training.json"; exit 1; }
grep -q '"pass":"dinput"' BENCH_training.json \
    || { echo "FAIL: dinput entries missing from BENCH_training.json"; exit 1; }

echo "==> BENCH_training.json: fused_step section present, bitwise, zero boundary words"
# the hard invariants (fused step bitwise vs the layer-by-layer SGD oracle,
# measured traffic == analytic model) are asserted INSIDE the bench — a
# violation panics it. Here we gate on the fields being present and on the
# fused step's boundaries actually being dry.
grep -q '"fused_step":' BENCH_training.json \
    || { echo "FAIL: fused_step section missing from BENCH_training.json"; exit 1; }
grep -q '"step_bitwise":true' BENCH_training.json \
    || { echo "FAIL: no builtin network runs its fused step bitwise"; exit 1; }
grep -q '"boundary_words_fused":0' BENCH_training.json \
    || { echo "FAIL: fused training step moved words across a fused boundary"; exit 1; }

echo "==> BENCH_network.json: fused speedup fields + packed-vs-reference gate + halo savings"
grep -q '"speedup_fused_vs_layered":' BENCH_network.json \
    || { echo "FAIL: speedup_fused_vs_layered missing from BENCH_network.json"; exit 1; }
# the packed fused microkernel must not regress below the fused naive
# baseline on any builtin network (the bench applies a 5% noise slack)
if grep -q '"fused_packed_ge_reference":false' BENCH_network.json; then
    echo "FAIL: fused packed throughput regressed below the fused naive baseline"
    exit 1
fi
grep -q '"halo_saved_words_total":' BENCH_network.json \
    || { echo "FAIL: halo_saved_words_total missing from BENCH_network.json"; exit 1; }
# the sliding-window halo cache must save recompute/re-read words on at
# least one network (a nonzero total starts with a nonzero digit)
grep -Eq '"halo_saved_words_total":[1-9]' BENCH_network.json \
    || { echo "FAIL: halo cache saved no words on any builtin network"; exit 1; }

echo "==> BENCH_parallel.json: measured exchange == analytic parallel volume for every strategy"
# the hard gates (bitwise vs the staged engine, verify_exchange) run INSIDE
# the bench — a violation panics it. Here we assert each strategy's rows
# carry the exactness flag (keys are alphabetical: measured_vs_bound_ok
# precedes strategy within a row object).
for strategy in batch channel spatial; do
    grep -Eq '"measured_vs_bound_ok":true[^}]*"strategy":"'"$strategy"'"' BENCH_parallel.json \
        || { echo "FAIL: no exact-exchange row for strategy $strategy in BENCH_parallel.json"; exit 1; }
    if grep -Eq '"measured_vs_bound_ok":false[^}]*"strategy":"'"$strategy"'"' BENCH_parallel.json; then
        echo "FAIL: strategy $strategy has a row whose measured exchange != the analytic model"
        exit 1
    fi
done

echo "==> BENCH_parallel.json: sharded speedup recorded at P=4"
# the speedup>1 acceptance asserts INSIDE the bench when >= 4 cores are
# available; here we only require the field to be present in the document
grep -q '"speedup_gt1_at_p4":' BENCH_parallel.json \
    || { echo "FAIL: speedup_gt1_at_p4 missing from BENCH_parallel.json"; exit 1; }

if cargo fmt --version >/dev/null 2>&1; then
    echo "==> cargo fmt --check"
    cargo fmt --check
else
    echo "SKIP: rustfmt not installed"
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "==> cargo clippy --all-targets -- -D warnings"
    cargo clippy --all-targets -- -D warnings
else
    echo "SKIP: clippy not installed"
fi

# The pjrt backend needs the external `xla` crate; the offline image does
# not ship it. Compile-check the feature only when a dependency line is
# present (see the [features] comment in Cargo.toml).
if grep -Eq '^\s*xla\s*=' Cargo.toml; then
    echo "==> cargo check --features pjrt"
    cargo check --features pjrt
else
    echo "SKIP: pjrt feature check (xla crate not wired into Cargo.toml)"
fi

echo "CI OK"
