"""L1: im2col + tiled-matmul comparison kernel.

im2col is the baseline the paper compares its blocking against (Figures 2-4).
The lowering is the classical one: gather every receptive field into a row of
a patch matrix, then multiply by the reshaped filter with a Pallas tiled
matmul (the part whose communication the paper charges to the matmul bound
of Kwasniewski et al. [12]).

The patch gather is pure jnp (it is data movement, not compute); the matmul
is a Pallas kernel so the MXU-bound part of im2col also exercises the
Pallas/VMEM path.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def im2col_patches(x, w_f, h_f, stride_w=1, stride_h=1, out_w=None, out_h=None):
    """Lower Input (N,cI,WI,HI) to the patch matrix (N*wO*hO, cI*wF*hF)."""
    n, c_i, w_i, h_i = x.shape
    if out_w is None:
        out_w = (w_i - w_f) // stride_w + 1
    if out_h is None:
        out_h = (h_i - h_f) // stride_h + 1
    cols = []
    for i6 in range(w_f):
        for i7 in range(h_f):
            patch = x[:, :, i6 : i6 + stride_w * (out_w - 1) + 1 : stride_w,
                          i7 : i7 + stride_h * (out_h - 1) + 1 : stride_h]
            # (N, cI, wO, hO) -> (N, wO, hO, cI)
            cols.append(jnp.transpose(patch, (0, 2, 3, 1)))
    # stack taps last: (N, wO, hO, wF*hF, cI) -> rows (N*wO*hO, cI*wF*hF)
    stacked = jnp.stack(cols, axis=3)
    return stacked.reshape(n * out_w * out_h, w_f * h_f * c_i), out_w, out_h


def _matmul_kernel(a_ref, b_ref, o_ref, *, acc_dtype):
    k = pl.program_id(2)
    part = jnp.dot(a_ref[...].astype(acc_dtype), b_ref[...].astype(acc_dtype),
                   preferred_element_type=acc_dtype)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = part

    @pl.when(k > 0)
    def _accum():
        o_ref[...] = o_ref[...] + part


def matmul_pallas(a, b, block_m=None, block_n=None, block_k=None,
                  acc_dtype=jnp.float32, interpret=True):
    """Tiled (bM, bK) x (bK, bN) Pallas matmul with accumulation over K."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    b_m = block_m or m
    b_n = block_n or n
    b_k = block_k or k
    assert m % b_m == 0 and n % b_n == 0 and k % b_k == 0, (
        f"blocks must divide dims: M={m}/{b_m} N={n}/{b_n} K={k}/{b_k}")
    grid = (m // b_m, n // b_n, k // b_k)
    kernel = functools.partial(_matmul_kernel, acc_dtype=acc_dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b_m, b_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((b_k, b_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((b_m, b_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), acc_dtype),
        interpret=interpret,
    )(a, b)


def conv7nl_im2col(x, w, stride_w=1, stride_h=1, out_w=None, out_h=None,
                   block_m=None, block_n=None, block_k=None,
                   acc_dtype=jnp.float32, interpret=True):
    """Full im2col convolution: gather + Pallas matmul + reshape back."""
    n, c_i, w_i, h_i = x.shape
    c_i2, c_o, w_f, h_f = w.shape
    assert c_i == c_i2
    patches, ow, oh = im2col_patches(x, w_f, h_f, stride_w, stride_h,
                                     out_w, out_h)
    # Filter (cI, cO, wF, hF) -> (wF*hF*cI, cO), tap-major to match patches.
    wmat = jnp.transpose(w, (2, 3, 0, 1)).reshape(w_f * h_f * c_i, c_o)
    out = matmul_pallas(patches, wmat, block_m, block_n, block_k,
                        acc_dtype=acc_dtype, interpret=interpret)
    # rows are (N, wO, hO)-major -> (N, cO, wO, hO)
    return jnp.transpose(out.reshape(n, ow, oh, c_o), (0, 3, 1, 2))
