"""L1: gradient (backward) convolutions as Pallas kernels.

Training a CNN runs three 7NL-shaped computations per layer (the paper's
bounds apply to each — they are 7NL CNN instances with permuted roles):

  forward : Out(n,co,w,h)   += In(n,ci,σw+i6,σh+i7) · F(ci,co,i6,i7)
  dFilter : dF(ci,co,i6,i7) += In(n,ci,σw+i6,σh+i7) · dOut(n,co,w,h)
  dInput  : dIn(n,ci,x,y)   += dOut(n,co,w,h) · F(ci,co,i6,i7)
            where x = σw·w + i6, y = σh·h + i7

dFilter is a contraction over (n, w, h) — channels play the matmul roles.
dInput is a scatter under stride; we compute it as the transposed form
(full correlation with the flipped filter for σ=1; strided via lax for the
oracle and an explicit tap loop in Pallas).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# ------------------------------------------------------------------ dFilter

def _dfilter_kernel(x_ref, g_ref, o_ref, *, stride_w, stride_h, out_w, out_h,
                    acc_dtype):
    """One (bcI, bcO) filter-gradient tile; accumulates over the batch grid
    axis (axis 2)."""
    nb = pl.program_id(2)

    x = x_ref[...].astype(acc_dtype)   # (bN, bcI, WI, HI)
    g = g_ref[...].astype(acc_dtype)   # (bN, bcO, wO, hO)
    w_f, h_f = o_ref.shape[2], o_ref.shape[3]
    sw, sh = stride_w, stride_h

    acc = jnp.zeros(o_ref.shape, dtype=acc_dtype)
    for i6 in range(w_f):
        for i7 in range(h_f):
            patch = x[:, :, i6 : i6 + sw * (out_w - 1) + 1 : sw,
                          i7 : i7 + sh * (out_h - 1) + 1 : sh]
            # contract over (n, w, h): (bN,bcI,wO,hO) x (bN,bcO,wO,hO)
            tap = jnp.einsum("ncwh,nowh->co", patch, g,
                             preferred_element_type=acc_dtype)
            acc = acc.at[:, :, i6, i7].add(tap)

    @pl.when(nb == 0)
    def _init():
        o_ref[...] = acc

    @pl.when(nb > 0)
    def _accum():
        o_ref[...] = o_ref[...] + acc


def dfilter_pallas(x, g, filt_w, filt_h, stride_w=1, stride_h=1,
                   block_n=None, block_ci=None, block_co=None,
                   acc_dtype=jnp.float32, interpret=True):
    """Filter gradient dF(cI,cO,wF,hF) from input x and output grad g."""
    n, c_i, w_i, h_i = x.shape
    n2, c_o, out_w, out_h = g.shape
    assert n == n2
    b_n = block_n or n
    b_ci = block_ci or c_i
    b_co = block_co or c_o
    assert n % b_n == 0 and c_i % b_ci == 0 and c_o % b_co == 0

    grid = (c_i // b_ci, c_o // b_co, n // b_n)
    kernel = functools.partial(
        _dfilter_kernel, stride_w=stride_w, stride_h=stride_h,
        out_w=out_w, out_h=out_h, acc_dtype=acc_dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b_n, b_ci, w_i, h_i), lambda i, j, k: (k, i, 0, 0)),
            pl.BlockSpec((b_n, b_co, out_w, out_h), lambda i, j, k: (k, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((b_ci, b_co, filt_w, filt_h),
                               lambda i, j, k: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c_i, c_o, filt_w, filt_h), acc_dtype),
        interpret=interpret,
    )(x, g)


# ------------------------------------------------------------------- dInput

def _dinput_kernel(g_ref, w_ref, o_ref, *, stride_w, stride_h, in_w, in_h,
                   acc_dtype):
    """One (bN, bcI) input-gradient tile; accumulates over the cO grid axis
    (axis 2). The scatter over strided taps is expressed as, per tap,
    a dilated add into the (WI, HI) canvas."""
    co = pl.program_id(2)

    g = g_ref[...].astype(acc_dtype)   # (bN, bcO, wO, hO)
    w = w_ref[...].astype(acc_dtype)   # (bcI, bcO, wF, hF)
    w_f, h_f = w.shape[2], w.shape[3]
    out_w, out_h = g.shape[2], g.shape[3]
    sw, sh = stride_w, stride_h

    acc = jnp.zeros(o_ref.shape, dtype=acc_dtype)
    for i6 in range(w_f):
        for i7 in range(h_f):
            tap = w[:, :, i6, i7]      # (bcI, bcO)
            contrib = jnp.einsum("nowh,co->ncwh", g, tap,
                                 preferred_element_type=acc_dtype)
            # scatter dIn[:, :, σw·w+i6, σh·h+i7] += contrib[:, :, w, h],
            # expressed as interior ("dilation") padding — avoids scatter
            # index constants that pallas kernels cannot capture
            padded = jax.lax.pad(
                contrib, jnp.zeros((), acc_dtype),
                ((0, 0, 0), (0, 0, 0),
                 (i6, in_w - i6 - (sw * (out_w - 1) + 1), sw - 1),
                 (i7, in_h - i7 - (sh * (out_h - 1) + 1), sh - 1)))
            acc = acc + padded

    @pl.when(co == 0)
    def _init():
        o_ref[...] = acc

    @pl.when(co > 0)
    def _accum():
        o_ref[...] = o_ref[...] + acc


def dinput_pallas(g, w, in_w, in_h, stride_w=1, stride_h=1,
                  block_n=None, block_ci=None, block_co=None,
                  acc_dtype=jnp.float32, interpret=True):
    """Input gradient dIn(N,cI,WI,HI) from output grad g and filter w."""
    n, c_o, out_w, out_h = g.shape
    c_i, c_o2, w_f, h_f = w.shape
    assert c_o == c_o2
    b_n = block_n or n
    b_ci = block_ci or c_i
    b_co = block_co or c_o
    assert n % b_n == 0 and c_i % b_ci == 0 and c_o % b_co == 0

    grid = (n // b_n, c_i // b_ci, c_o // b_co)
    kernel = functools.partial(
        _dinput_kernel, stride_w=stride_w, stride_h=stride_h,
        in_w=in_w, in_h=in_h, acc_dtype=acc_dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b_n, b_co, out_w, out_h), lambda i, j, k: (i, k, 0, 0)),
            pl.BlockSpec((b_ci, b_co, w_f, h_f), lambda i, j, k: (j, k, 0, 0)),
        ],
        out_specs=pl.BlockSpec((b_n, b_ci, in_w, in_h),
                               lambda i, j, k: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c_i, in_w, in_h), acc_dtype),
        interpret=interpret,
    )(g, w)


# ------------------------------------------------------------------ oracles

def dfilter_ref(x, g, filt_w, filt_h, stride_w=1, stride_h=1,
                acc_dtype=jnp.float32):
    """Filter gradient via explicit tap loop (transparent oracle)."""
    n, c_i, w_i, h_i = x.shape
    _, c_o, out_w, out_h = g.shape
    acc = jnp.zeros((c_i, c_o, filt_w, filt_h), dtype=acc_dtype)
    for i6 in range(filt_w):
        for i7 in range(filt_h):
            patch = x[:, :, i6 : i6 + stride_w * (out_w - 1) + 1 : stride_w,
                          i7 : i7 + stride_h * (out_h - 1) + 1 : stride_h]
            acc = acc.at[:, :, i6, i7].set(
                jnp.einsum("ncwh,nowh->co", patch.astype(acc_dtype),
                           g.astype(acc_dtype)))
    return acc


def dinput_ref(g, w, in_w, in_h, stride_w=1, stride_h=1,
               acc_dtype=jnp.float32):
    """Input gradient via explicit scatter loop (transparent oracle)."""
    n, c_o, out_w, out_h = g.shape
    c_i = w.shape[0]
    w_f, h_f = w.shape[2], w.shape[3]
    acc = jnp.zeros((n, c_i, in_w, in_h), dtype=acc_dtype)
    for i6 in range(w_f):
        for i7 in range(h_f):
            tap = w[:, :, i6, i7]
            contrib = jnp.einsum("nowh,co->ncwh", g.astype(acc_dtype),
                                 tap.astype(acc_dtype))
            acc = acc.at[:, :, i6 : i6 + stride_w * (out_w - 1) + 1 : stride_w,
                               i7 : i7 + stride_h * (out_h - 1) + 1 : stride_h
                         ].add(contrib)
    return acc
