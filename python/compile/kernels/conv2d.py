"""L1: Pallas direct-convolution kernel with the paper's blocking.

The paper's communication-optimal *blocking* (Section 3.2) tiles the seven
loops so that one input block, one filter block and one output block fit in
fast memory simultaneously (constraint (6)).  On a TPU-style machine the
fast memory is VMEM, and the HBM->VMEM schedule is expressed with a Pallas
grid + BlockSpecs:

    grid = (N/bN, cO/bcO, cI/bcI)          -- cI is the reduction axis
    Input  block: (bN, bcI, WI, HI)        staged per (n, ci)
    Filter block: (bcI, bcO, wF, hF)       staged per (co, ci)
    Output block: (bN, bcO, wO, hO)        held across the cI axis and
                                           accumulated in place (the GEMMINI
                                           "accumulator" analogue)

Spatial (wO/hO) tiling needs halo regions that Pallas block-index maps cannot
express, so it lives one level up in model.py (conv_blocked), which carves
the image into overlapping patches and issues one pallas_call per patch —
exactly the role the paper's outer loops over (i4, i5) blocks play.

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret mode lowers the kernel to plain HLO so the Rust
runtime can execute the AOT artifact.  Real-TPU performance is estimated
from the VMEM footprint / MXU utilization analysis in DESIGN.md.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv_kernel(x_ref, w_ref, o_ref, *, stride_w, stride_h, out_w, out_h,
                 n_ci_blocks, acc_dtype):
    """Pallas kernel body: direct conv of one (bN, bcI) x (bcI, bcO) tile.

    Accumulates into o_ref across the cI grid axis (axis 2).
    """
    ci = pl.program_id(2)

    x = x_ref[...].astype(acc_dtype)   # (bN, bcI, WI, HI)
    w = w_ref[...].astype(acc_dtype)   # (bcI, bcO, wF, hF)
    w_f, h_f = w.shape[2], w.shape[3]
    sw, sh = stride_w, stride_h

    acc = jnp.zeros(o_ref.shape, dtype=acc_dtype)
    # Static unroll over filter taps: each tap is a strided slice + a
    # (bN*wO*hO, bcI) x (bcI, bcO) contraction that maps onto the MXU.
    for i6 in range(w_f):
        for i7 in range(h_f):
            patch = x[:, :, i6 : i6 + sw * (out_w - 1) + 1 : sw,
                          i7 : i7 + sh * (out_h - 1) + 1 : sh]
            tap = w[:, :, i6, i7]      # (bcI, bcO)
            acc = acc + jnp.einsum("ncwh,co->nowh", patch, tap,
                                   preferred_element_type=acc_dtype)

    # First reduction step initializes the accumulator tile; later steps add.
    @pl.when(ci == 0)
    def _init():
        o_ref[...] = acc

    @pl.when(ci > 0)
    def _accum():
        o_ref[...] = o_ref[...] + acc


def conv7nl_pallas(x, w, stride_w=1, stride_h=1, out_w=None, out_h=None,
                   block_n=None, block_ci=None, block_co=None,
                   acc_dtype=jnp.float32, interpret=True):
    """Paper-blocked direct convolution as a Pallas call.

    Block sizes default to the full dimension (single tile). The LP tiling
    from the Rust side (or python/compile/tiling.py) supplies bN/bcI/bcO.
    """
    n, c_i, w_i, h_i = x.shape
    c_i2, c_o, w_f, h_f = w.shape
    assert c_i == c_i2
    if out_w is None:
        out_w = (w_i - w_f) // stride_w + 1
    if out_h is None:
        out_h = (h_i - h_f) // stride_h + 1
    b_n = block_n or n
    b_ci = block_ci or c_i
    b_co = block_co or c_o
    assert n % b_n == 0 and c_i % b_ci == 0 and c_o % b_co == 0, (
        f"blocks must divide dims: N={n}/{b_n} cI={c_i}/{b_ci} cO={c_o}/{b_co}")

    grid = (n // b_n, c_o // b_co, c_i // b_ci)

    kernel = functools.partial(
        _conv_kernel, stride_w=stride_w, stride_h=stride_h,
        out_w=out_w, out_h=out_h, n_ci_blocks=grid[2], acc_dtype=acc_dtype)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            # Input: staged per (n-block, ci-block); full spatial extent.
            pl.BlockSpec((b_n, b_ci, w_i, h_i), lambda i, j, k: (i, k, 0, 0)),
            # Filter: staged per (ci-block, co-block).
            pl.BlockSpec((b_ci, b_co, w_f, h_f), lambda i, j, k: (k, j, 0, 0)),
        ],
        # Output: revisited across the cI axis (k ignored) -> accumulation.
        out_specs=pl.BlockSpec((b_n, b_co, out_w, out_h),
                               lambda i, j, k: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, c_o, out_w, out_h), acc_dtype),
        interpret=interpret,
    )(x, w)
