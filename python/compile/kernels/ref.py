"""Pure-jnp correctness oracle for the 7NL CNN direct convolution.

The paper's model (eq. 1):

    Output(i1,i3,i4,i5) += Input(i1,i2, sw*i4+i6, sh*i5+i7) * Filter(i2,i3,i6,i7)

with
    Input : (N, cI, WI, HI)   where WI >= sw*(wO-1)+wF, HI >= sh*(hO-1)+hF
    Filter: (cI, cO, wF, hF)
    Output: (N, cO, wO, hO)

This file is the oracle every kernel is validated against. It is written in
the most transparent way possible (a loop over the filter taps with strided
slicing) so that its correctness is auditable by inspection, and it is also
cross-checked against jax.lax.conv_general_dilated in the test suite.
"""

import jax.numpy as jnp


def conv7nl_ref(x, w, stride_w=1, stride_h=1, out_w=None, out_h=None,
                acc_dtype=jnp.float32):
    """Direct 7NL CNN convolution, reference semantics.

    Args:
      x: Input, shape (N, cI, WI, HI).
      w: Filter, shape (cI, cO, wF, hF).
      stride_w, stride_h: strides sigma_w, sigma_h.
      out_w, out_h: output spatial dims; default to the maximal valid size
        floor((WI - wF)/sw) + 1.
      acc_dtype: accumulation dtype (the paper's "output precision" —
        GEMMINI accumulates at 32 bits regardless of input precision).

    Returns:
      Output, shape (N, cO, out_w, out_h), dtype acc_dtype.
    """
    n, c_i, w_i, h_i = x.shape
    c_i2, c_o, w_f, h_f = w.shape
    assert c_i == c_i2, f"channel mismatch {c_i} vs {c_i2}"
    sw, sh = stride_w, stride_h
    if out_w is None:
        out_w = (w_i - w_f) // sw + 1
    if out_h is None:
        out_h = (h_i - h_f) // sh + 1
    assert sw * (out_w - 1) + w_f <= w_i, "input too small in w"
    assert sh * (out_h - 1) + h_f <= h_i, "input too small in h"

    acc = jnp.zeros((n, c_o, out_w, out_h), dtype=acc_dtype)
    for i6 in range(w_f):
        for i7 in range(h_f):
            # Input(i1, i2, sw*i4 + i6, sh*i5 + i7) over all (i4, i5)
            patch = x[:, :, i6 : i6 + sw * (out_w - 1) + 1 : sw,
                          i7 : i7 + sh * (out_h - 1) + 1 : sh]
            tap = w[:, :, i6, i7]  # (cI, cO)
            acc = acc + jnp.einsum(
                "ncwh,co->nowh",
                patch.astype(acc_dtype),
                tap.astype(acc_dtype),
            )
    return acc


def conv7nl_lax(x, w, stride_w=1, stride_h=1, acc_dtype=jnp.float32):
    """Same computation via jax.lax.conv_general_dilated (second oracle)."""
    import jax.lax as lax

    # lax convention: lhs (N, C, W, H), rhs (O, I, W, H)
    rhs = jnp.transpose(w, (1, 0, 2, 3)).astype(acc_dtype)
    return lax.conv_general_dilated(
        x.astype(acc_dtype), rhs,
        window_strides=(stride_w, stride_h),
        padding="VALID",
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
