"""AOT compile path: lower every model variant to HLO *text* artifacts.

HLO text (not `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids, which xla_extension 0.5.1 (the
version the published `xla` 0.1.6 crate links) rejects with
`proto.id() <= INT_MAX`. The text parser reassigns ids and round-trips
cleanly. See /opt/xla-example/README.md.

Run as  `cd python && python -m compile.aot --out-dir ../artifacts`
(the Makefile target `artifacts` does exactly this, and is a no-op when the
outputs are newer than the compile/ sources).

Besides the .hlo.txt files this writes artifacts/manifest.json describing
every artifact's entry shapes so the Rust runtime can set up buffers without
parsing HLO. The manifest carries two sections the Rust side consumes:

  * "artifacts": one entry per lowered module -- name, kind ("blocked",
    "im2col", "dfilter", "dinput", "network"), path, inputs (shape list in
    call order), output shape, and the MAC count `updates`;
  * "networks": one entry per exactly-chaining pipeline (see
    network_manifest_entry for the stage schema), so backends that execute
    pipelines natively (the Rust fused planner) can run the same plans as
    Manifest::builtin; file-based backends keep using the lowered HLO.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels.grad import dfilter_pallas, dinput_pallas
from .model import (ConvSpec, conv_layer, conv_layer_im2col, network_forward,
                    single_layer_specs, tiny_resnet_specs)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec_entry(spec: ConvSpec, kind: str, path: str, inputs, output):
    return {
        "name": spec.name,
        "kind": kind,
        "path": os.path.basename(path),
        "inputs": [list(s) for s in inputs],
        "output": list(output),
        "stride_w": spec.stride_w,
        "stride_h": spec.stride_h,
        "out_w": spec.out_w,
        "out_h": spec.out_h,
        "filt_w": spec.filt_w,
        "filt_h": spec.filt_h,
        "updates": spec.updates,
    }


def lower_layer(spec: ConvSpec, kind: str):
    """Lower a single conv layer (blocked-pallas or im2col) to HLO text."""
    fn = conv_layer if kind == "blocked" else conv_layer_im2col

    def entry(x, w):
        return (fn(x, w, spec),)

    x_spec = jax.ShapeDtypeStruct(spec.input_shape, jnp.float32)
    w_spec = jax.ShapeDtypeStruct(spec.filter_shape, jnp.float32)
    return to_hlo_text(jax.jit(entry).lower(x_spec, w_spec))


def lower_dfilter(spec: ConvSpec):
    """Lower the filter-gradient kernel for a layer: (x, dOut) -> (dF,)."""

    def entry(x, g):
        return (dfilter_pallas(x, g, spec.filt_w, spec.filt_h,
                               spec.stride_w, spec.stride_h,
                               block_ci=spec.block_ci, block_co=spec.block_co),)

    x_spec = jax.ShapeDtypeStruct(spec.input_shape, jnp.float32)
    g_spec = jax.ShapeDtypeStruct(spec.output_shape, jnp.float32)
    return to_hlo_text(jax.jit(entry).lower(x_spec, g_spec))


def lower_dinput(spec: ConvSpec):
    """Lower the input-gradient kernel for a layer: (dOut, w) -> (dIn,)."""

    def entry(g, w):
        return (dinput_pallas(g, w, spec.in_w, spec.in_h,
                              spec.stride_w, spec.stride_h,
                              block_ci=spec.block_ci, block_co=spec.block_co),)

    g_spec = jax.ShapeDtypeStruct(spec.output_shape, jnp.float32)
    w_spec = jax.ShapeDtypeStruct(spec.filter_shape, jnp.float32)
    return to_hlo_text(jax.jit(entry).lower(g_spec, w_spec))


def lower_network(specs, batch: int):
    """Lower the whole tiny CNN forward pass to one HLO module."""
    first = specs[0]

    def entry(x, *weights):
        return (network_forward(x, weights, specs),)

    x_spec = jax.ShapeDtypeStruct(first.input_shape, jnp.float32)
    w_specs = [jax.ShapeDtypeStruct(s.filter_shape, jnp.float32)
               for s in specs]
    return to_hlo_text(jax.jit(entry).lower(x_spec, *w_specs))


def network_manifest_entry(name: str, specs) -> dict:
    """The `networks` manifest entry for one exactly-chaining spec list.

    Schema (mirrors runtime/manifest.rs::Manifest::parse, which validates
    it strictly — see the manifest notes in the module docstring):

        {"name": <str>,
         "stages": [{"shape": [N, cI, cO, wO, hO, wF, hF, sw, sh],
                     "precision": [pI, pF, pO]}, ...]}

    `precision` is optional (defaults to uniform f32 words on the Rust
    side); every boundary must satisfy cI(k+1) == cO(k) and
    sigma(k+1)*out(k+1) + filt(k+1) == out(k) per axis, which this helper
    re-checks so a drifted spec list fails at build time, not at load.
    """
    for prev, nxt in zip(specs, specs[1:]):
        assert prev.c_out == nxt.c_in, f"{name}: channel chain broken"
        assert (prev.out_w, prev.out_h) == (nxt.in_w, nxt.in_h), (
            f"{name}: spatial chain broken at {nxt.name} "
            f"({prev.out_w}x{prev.out_h} -> {nxt.in_w}x{nxt.in_h})")
    return {
        "name": name,
        "stages": [{
            "shape": [s.n, s.c_in, s.c_out, s.out_w, s.out_h,
                      s.filt_w, s.filt_h, s.stride_w, s.stride_h],
            "precision": [1.0, 1.0, 1.0],
        } for s in specs],
    }


def build_all(out_dir: str, batch: int = 4) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"batch": batch, "artifacts": []}

    for spec in single_layer_specs(batch):
        for kind in ("blocked", "im2col"):
            fname = f"layer_{spec.name}_{kind}.hlo.txt"
            path = os.path.join(out_dir, fname)
            text = lower_layer(spec, kind)
            with open(path, "w") as f:
                f.write(text)
            manifest["artifacts"].append(_spec_entry(
                spec, kind, path, [spec.input_shape, spec.filter_shape],
                spec.output_shape))
            print(f"wrote {path} ({len(text)} chars)")

    # backward-pass artifacts for the first unit layer (training path)
    grad_spec = single_layer_specs(batch)[0]
    for kind, lower, inputs, output in [
        ("dfilter", lower_dfilter,
         [grad_spec.input_shape, grad_spec.output_shape],
         grad_spec.filter_shape),
        ("dinput", lower_dinput,
         [grad_spec.output_shape, grad_spec.filter_shape],
         grad_spec.input_shape),
    ]:
        fname = f"layer_{grad_spec.name}_{kind}.hlo.txt"
        path = os.path.join(out_dir, fname)
        text = lower(grad_spec)
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"].append(_spec_entry(
            grad_spec, kind, path, inputs, output))
        print(f"wrote {path} ({len(text)} chars)")

    net_specs = tiny_resnet_specs(batch)
    net_path = os.path.join(out_dir, "network_tiny_resnet.hlo.txt")
    text = lower_network(net_specs, batch)
    with open(net_path, "w") as f:
        f.write(text)
    last = net_specs[-1]
    manifest["artifacts"].append({
        "name": "tiny_resnet",
        "kind": "network",
        "path": os.path.basename(net_path),
        "inputs": [list(net_specs[0].input_shape)]
                  + [list(s.filter_shape) for s in net_specs],
        "output": list(last.output_shape),
        "layers": [s.name for s in net_specs],
        "updates": sum(s.updates for s in net_specs),
    })
    print(f"wrote {net_path} ({len(text)} chars)")

    # the networks section: lets runtimes that execute pipelines natively
    # (the Rust native backend's fused planner) run the same chain as
    # Manifest::builtin, while file-based backends (PJRT) keep loading the
    # lowered HLO module above (ExecBackend::supports_networks gates the
    # routing on the Rust side)
    manifest["networks"] = [network_manifest_entry("tiny_resnet", net_specs)]

    man_path = os.path.join(out_dir, "manifest.json")
    with open(man_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {man_path}")
    return manifest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()
    build_all(args.out_dir, args.batch)


if __name__ == "__main__":
    main()
