"""Build-time blocking selection for the Pallas kernels (the python mirror
of the Rust §3.2 LP, specialized to the kernel's VMEM constraint).

The L1 kernel tiles (N, cI, cO) inside the Pallas grid; the L2 layer tiles
(wO, hO) spatially. This module picks divisor block sizes so that one
input block + one filter block + one f32 output block fit a VMEM budget —
constraint (6) of the paper with M = vmem_words — maximizing updates per
tile greedily over the divisor grid (the integral analogue of the LP;
ranges here are tiny so exhaustion is exact, like the Rust gemmini_opt).
"""

import dataclasses
from typing import Optional


def divisors(n: int):
    return [d for d in range(1, n + 1) if n % d == 0]


@dataclasses.dataclass(frozen=True)
class KernelBlocking:
    block_n: int
    block_ci: int
    block_co: int
    block_wo: int
    block_ho: int
    footprint_words: int


def footprint_words(n, ci, co, bwo, bho, filt_w, filt_h,
                    stride_w, stride_h) -> int:
    """Words (f32) of the three blocks under the paper's constraint (6)."""
    in_w = stride_w * (bwo - 1) + filt_w
    in_h = stride_h * (bho - 1) + filt_h
    return (n * ci * in_w * in_h          # input block
            + ci * co * filt_w * filt_h   # filter block
            + n * co * bwo * bho)         # output (accumulator) block


def choose_blocking(n, c_in, c_out, out_w, out_h, filt_w, filt_h,
                    stride_w=1, stride_h=1,
                    vmem_words: int = 4 * 1024 * 1024,
                    spatial: bool = True) -> Optional[KernelBlocking]:
    """Exhaustive divisor search maximizing updates/tile under the VMEM cap.

    Returns None when even the unit tile does not fit (never happens for
    sane layers and VMEM budgets).
    """
    best = None
    best_updates = -1
    wo_cands = divisors(out_w) if spatial else [out_w]
    ho_cands = divisors(out_h) if spatial else [out_h]
    for bci in divisors(c_in):
        for bco in divisors(c_out):
            for bwo in wo_cands:
                for bho in ho_cands:
                    for bn in divisors(n):
                        fp = footprint_words(bn, bci, bco, bwo, bho,
                                             filt_w, filt_h,
                                             stride_w, stride_h)
                        if fp > vmem_words:
                            break  # larger bn only grows the tile
                        updates = bn * bci * bco * bwo * bho
                        if updates > best_updates or (
                                updates == best_updates
                                and fp < best.footprint_words):
                            best_updates = updates
                            best = KernelBlocking(bn, bci, bco, bwo, bho, fp)
    return best
