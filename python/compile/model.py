"""L2: JAX model — conv layers (calling the L1 Pallas kernels) and a small
CNN forward pass, the compute graph that aot.py lowers to HLO artifacts.

The paper's blocking has two parts:
  * channel/batch tiling, expressed inside the Pallas grid (kernels/conv2d.py)
  * spatial (wO, hO) tiling with halos, expressed HERE by carving the input
    image into overlapping patches and issuing one pallas_call per patch —
    this is the role of the outer (i4, i5) blocks in the paper's loop nest.

Everything here is build-time Python: jax.jit(...).lower() -> HLO text ->
rust runtime. Nothing in this file runs at request time.
"""

import dataclasses
from typing import Optional, Sequence

import jax
import jax.numpy as jnp

from .kernels.conv2d import conv7nl_pallas
from .kernels.im2col import conv7nl_im2col


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """One 7NL CNN layer: shapes, strides and the blocking to use."""
    name: str
    n: int
    c_in: int
    c_out: int
    out_w: int
    out_h: int
    filt_w: int
    filt_h: int
    stride_w: int = 1
    stride_h: int = 1
    # blocking (paper Section 3.2); None = full dimension
    block_n: Optional[int] = None
    block_ci: Optional[int] = None
    block_co: Optional[int] = None
    block_wo: Optional[int] = None
    block_ho: Optional[int] = None

    @property
    def in_w(self) -> int:
        # paper's convention: WI = sigma_w * wO + wF (slightly padded vs the
        # tight sw*(wO-1)+wF so the size formula |I| matches the paper).
        return self.stride_w * self.out_w + self.filt_w

    @property
    def in_h(self) -> int:
        return self.stride_h * self.out_h + self.filt_h

    @property
    def input_shape(self):
        return (self.n, self.c_in, self.in_w, self.in_h)

    @property
    def filter_shape(self):
        return (self.c_in, self.c_out, self.filt_w, self.filt_h)

    @property
    def output_shape(self):
        return (self.n, self.c_out, self.out_w, self.out_h)

    @property
    def updates(self) -> int:
        """G = N cI cO wO hO wF hF, the total number of MACs."""
        return (self.n * self.c_in * self.c_out * self.out_w * self.out_h
                * self.filt_w * self.filt_h)


def conv_layer(x, w, spec: ConvSpec, acc_dtype=jnp.float32):
    """One blocked conv layer. Spatial tiling outside, Pallas grid inside."""
    b_wo = spec.block_wo or spec.out_w
    b_ho = spec.block_ho or spec.out_h
    assert spec.out_w % b_wo == 0 and spec.out_h % b_ho == 0, (
        f"{spec.name}: spatial blocks must divide output dims")
    sw, sh = spec.stride_w, spec.stride_h

    def tile(ti, tj):
        # overlapping input patch (halo = filter extent) for output tile
        x_tile = jax.lax.slice(
            x,
            (0, 0, ti * b_wo * sw, tj * b_ho * sh),
            (spec.n, spec.c_in,
             ti * b_wo * sw + sw * (b_wo - 1) + spec.filt_w,
             tj * b_ho * sh + sh * (b_ho - 1) + spec.filt_h))
        return conv7nl_pallas(
            x_tile, w, sw, sh, out_w=b_wo, out_h=b_ho,
            block_n=spec.block_n, block_ci=spec.block_ci,
            block_co=spec.block_co, acc_dtype=acc_dtype)

    rows = []
    for ti in range(spec.out_w // b_wo):
        cols = [tile(ti, tj) for tj in range(spec.out_h // b_ho)]
        rows.append(jnp.concatenate(cols, axis=3) if len(cols) > 1 else cols[0])
    return jnp.concatenate(rows, axis=2) if len(rows) > 1 else rows[0]


def conv_layer_im2col(x, w, spec: ConvSpec, acc_dtype=jnp.float32):
    """The im2col baseline for the same layer (Figure 2/3/4 comparisons)."""
    return conv7nl_im2col(x, w, spec.stride_w, spec.stride_h,
                          out_w=spec.out_w, out_h=spec.out_h,
                          acc_dtype=acc_dtype)


def network_forward(x, weights: Sequence, specs: Sequence[ConvSpec],
                    acc_dtype=jnp.float32):
    """A small CNN: chained blocked conv layers with ReLU between them.

    Consecutive specs must be spatially compatible: layer k+1's input shape
    equals (paper convention) sigma*out + filt of its own spec, so we pad the
    previous activation up to it (zero-padding at the boundary mimics the
    paper's slightly-oversized input arrays).
    """
    act = x
    for w, spec in zip(weights, specs):
        want = spec.input_shape
        have = act.shape
        assert have[0] == want[0] and have[1] == want[1], (
            f"{spec.name}: N/C mismatch {have} vs {want}")
        pad_w = want[2] - have[2]
        pad_h = want[3] - have[3]
        assert pad_w >= 0 and pad_h >= 0, (
            f"{spec.name}: activation {have} larger than expected {want}")
        if pad_w or pad_h:
            act = jnp.pad(act, ((0, 0), (0, 0), (0, pad_w), (0, pad_h)))
        act = conv_layer(act, w, spec, acc_dtype=acc_dtype)
        act = jax.nn.relu(act)
    return act


# ---------------------------------------------------------------------------
# Artifact model zoo: the scaled-down ResNet-ish stack used by the e2e driver.
# Shapes are chosen so interpret-mode Pallas stays fast on CPU while still
# exercising multi-block grids in every dimension the paper tiles.
# ---------------------------------------------------------------------------

def tiny_resnet_specs(batch: int = 4) -> list:
    """Three-stage downsampling CNN, block sizes from the LP tiling style.

    The chain satisfies the paper's input convention *exactly* at every
    boundary (sigma*out + filt of stage k+1 == out of stage k), so
    network_forward's upward padding is a no-op and aot.py can emit the
    chain as a `networks` manifest entry the Rust runtime's strict
    NetworkSpec validation accepts (the fused-pipeline path).
    """
    return [
        ConvSpec("conv1", batch, 3, 12, out_w=15, out_h=15, filt_w=5, filt_h=5,
                 stride_w=2, stride_h=2, block_ci=3, block_co=6,
                 block_wo=5, block_ho=5),
        ConvSpec("conv2", batch, 12, 16, out_w=12, out_h=12, filt_w=3, filt_h=3,
                 stride_w=1, stride_h=1, block_ci=4, block_co=8,
                 block_wo=6, block_ho=6),
        # 2x2/2 tail: in = 2*5 + 2 = 12 = conv2's out, an exact boundary
        ConvSpec("conv3", batch, 16, 32, out_w=5, out_h=5, filt_w=2, filt_h=2,
                 stride_w=2, stride_h=2, block_ci=8, block_co=16),
    ]


def single_layer_specs(batch: int = 4) -> list:
    """Standalone layer artifacts (one HLO file each) for the runtime tests
    and the per-layer serving path of the coordinator."""
    return [
        ConvSpec("unit3x3", batch, 8, 16, out_w=6, out_h=6, filt_w=3, filt_h=3,
                 stride_w=2, stride_h=2, block_ci=4, block_co=8),
        ConvSpec("unit1x1", batch, 16, 32, out_w=8, out_h=8, filt_w=1, filt_h=1,
                 stride_w=1, stride_h=1, block_ci=8, block_co=16),
        ConvSpec("unit5x5s1", batch, 4, 8, out_w=10, out_h=10, filt_w=5,
                 filt_h=5, stride_w=1, stride_h=1, block_ci=2, block_co=4,
                 block_wo=5, block_ho=5),
    ]
