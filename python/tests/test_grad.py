"""Backward-pass kernels vs (a) explicit-loop oracles and (b) jax autodiff
of the forward oracle — two independent checks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.grad import (dfilter_pallas, dfilter_ref, dinput_pallas,
                                  dinput_ref)
from compile.kernels.ref import conv7nl_ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


def autodiff_grads(x, w, g, sw, sh, out_w, out_h):
    """d/dx and d/dw of <conv(x, w), g> via jax.grad — ground truth."""
    def loss_x(xv):
        return jnp.vdot(conv7nl_ref(xv, w, sw, sh, out_w, out_h), g)

    def loss_w(wv):
        return jnp.vdot(conv7nl_ref(x, wv, sw, sh, out_w, out_h), g)

    return jax.grad(loss_x)(x), jax.grad(loss_w)(w)


@pytest.mark.parametrize("stride", [(1, 1), (2, 2), (2, 1)])
def test_refs_match_autodiff(stride):
    sw, sh = stride
    out_w, out_h = 5, 4
    wf, hf = 3, 3
    x = rand(0, (2, 4, sw * (out_w - 1) + wf, sh * (out_h - 1) + hf))
    w = rand(1, (4, 6, wf, hf))
    g = rand(2, (2, 6, out_w, out_h))
    dx_ad, dw_ad = autodiff_grads(x, w, g, sw, sh, out_w, out_h)
    dw = dfilter_ref(x, g, wf, hf, sw, sh)
    dx = dinput_ref(g, w, x.shape[2], x.shape[3], sw, sh)
    np.testing.assert_allclose(dw, dw_ad, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(dx, dx_ad, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("blocks", [(None, None, None), (2, 2, 3), (1, 4, 6)])
def test_dfilter_pallas_matches_ref(blocks):
    bn, bci, bco = blocks
    sw = sh = 1
    out_w = out_h = 6
    wf = hf = 3
    x = rand(3, (4, 4, out_w - 1 + wf, out_h - 1 + hf))
    g = rand(4, (4, 6, out_w, out_h))
    got = dfilter_pallas(x, g, wf, hf, sw, sh,
                         block_n=bn, block_ci=bci, block_co=bco)
    want = dfilter_ref(x, g, wf, hf, sw, sh)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_dfilter_pallas_strided():
    x = rand(5, (2, 3, 13, 11))
    g = rand(6, (2, 5, 6, 5))
    got = dfilter_pallas(x, g, 3, 3, 2, 2, block_n=1)
    want = dfilter_ref(x, g, 3, 3, 2, 2)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("blocks", [(None, None, None), (2, 2, 3), (1, 4, 2)])
def test_dinput_pallas_matches_ref(blocks):
    bn, bci, bco = blocks
    in_w = in_h = 8
    wf = hf = 3
    out_w, out_h = in_w - wf + 1, in_h - hf + 1
    g = rand(7, (2, 6, out_w, out_h))
    w = rand(8, (4, 6, wf, hf))
    got = dinput_pallas(g, w, in_w, in_h, 1, 1,
                        block_n=bn, block_ci=bci, block_co=bco)
    want = dinput_ref(g, w, in_w, in_h, 1, 1)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_dinput_pallas_strided():
    in_w, in_h = 13, 11
    g = rand(9, (2, 4, 6, 5))
    w = rand(10, (3, 4, 3, 3))
    got = dinput_pallas(g, w, in_w, in_h, 2, 2, block_co=2)
    want = dinput_ref(g, w, in_w, in_h, 2, 2)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 3),
    ci=st.sampled_from([1, 2, 4]),
    co=st.sampled_from([1, 2, 4]),
    wo=st.integers(2, 5),
    ho=st.integers(2, 5),
    wf=st.integers(1, 3),
    hf=st.integers(1, 3),
    sw=st.integers(1, 2),
    sh=st.integers(1, 2),
    seed=st.integers(0, 2**16),
)
def test_grads_match_autodiff_random(n, ci, co, wo, ho, wf, hf, sw, sh, seed):
    if sw > wf or sh > hf:
        return
    in_w = sw * (wo - 1) + wf
    in_h = sh * (ho - 1) + hf
    x = rand(seed, (n, ci, in_w, in_h))
    w = rand(seed + 1, (ci, co, wf, hf))
    g = rand(seed + 2, (n, co, wo, ho))
    dx_ad, dw_ad = autodiff_grads(x, w, g, sw, sh, wo, ho)
    dw = dfilter_pallas(x, g, wf, hf, sw, sh)
    dx = dinput_pallas(g, w, in_w, in_h, sw, sh)
    np.testing.assert_allclose(dw, dw_ad, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(dx, dx_ad, rtol=1e-3, atol=1e-3)
