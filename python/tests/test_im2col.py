"""L1 correctness: the im2col + Pallas-matmul baseline vs the oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.im2col import (conv7nl_im2col, im2col_patches,
                                    matmul_pallas)
from compile.kernels.ref import conv7nl_ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


def test_patches_shape_and_content():
    x = rand(0, (2, 3, 6, 6))
    patches, ow, oh = im2col_patches(x, 3, 3, 1, 1)
    assert (ow, oh) == (4, 4)
    assert patches.shape == (2 * 4 * 4, 3 * 3 * 3)
    # row 0 = receptive field of output (0, 0, 0), tap-major layout
    row0 = patches[0]
    want = jnp.stack([x[0, :, i6, i7] for i6 in range(3) for i7 in range(3)])
    np.testing.assert_allclose(row0, want.reshape(-1), rtol=1e-6)


def test_matmul_pallas_matches_jnp():
    a = rand(1, (12, 8))
    b = rand(2, (8, 6))
    got = matmul_pallas(a, b, block_m=4, block_n=3, block_k=2)
    np.testing.assert_allclose(got, a @ b, rtol=1e-5, atol=1e-5)


def test_matmul_pallas_single_tile():
    a = rand(3, (5, 7))
    b = rand(4, (7, 3))
    got = matmul_pallas(a, b)
    np.testing.assert_allclose(got, a @ b, rtol=1e-5, atol=1e-5)


def test_matmul_rejects_nondividing_blocks():
    a = rand(5, (5, 4))
    b = rand(6, (4, 4))
    with pytest.raises(AssertionError):
        matmul_pallas(a, b, block_m=2)


@pytest.mark.parametrize("stride", [(1, 1), (2, 2), (2, 1)])
def test_im2col_conv_matches_ref(stride):
    sw, sh = stride
    x = rand(7, (2, 4, 13, 11))
    w = rand(8, (4, 6, 3, 3))
    got = conv7nl_im2col(x, w, sw, sh)
    want = conv7nl_ref(x, w, sw, sh)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 3),
    ci=st.integers(1, 5),
    co=st.integers(1, 5),
    wo=st.integers(1, 5),
    ho=st.integers(1, 5),
    wf=st.integers(1, 3),
    hf=st.integers(1, 3),
    seed=st.integers(0, 2**16),
)
def test_im2col_random_shapes(n, ci, co, wo, ho, wf, hf, seed):
    in_w = (wo - 1) + wf
    in_h = (ho - 1) + hf
    x = rand(seed, (n, ci, in_w, in_h))
    w = rand(seed + 1, (ci, co, wf, hf))
    got = conv7nl_im2col(x, w, 1, 1, out_w=wo, out_h=ho)
    want = conv7nl_ref(x, w, 1, 1, out_w=wo, out_h=ho)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)
