"""python-side blocking selection: feasibility + consistency with the
Pallas kernel's block requirements."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.conv2d import conv7nl_pallas
from compile.kernels.ref import conv7nl_ref
from compile.tiling import choose_blocking, divisors, footprint_words

jax.config.update("jax_platform_name", "cpu")


def test_divisors():
    assert divisors(12) == [1, 2, 3, 4, 6, 12]
    assert divisors(1) == [1]


def test_blocking_fits_budget():
    b = choose_blocking(8, 64, 64, 56, 56, 3, 3, vmem_words=64 * 1024)
    assert b is not None
    assert b.footprint_words <= 64 * 1024
    # divisibility (the Pallas kernel asserts this)
    assert 8 % b.block_n == 0
    assert 64 % b.block_ci == 0 and 64 % b.block_co == 0
    assert 56 % b.block_wo == 0 and 56 % b.block_ho == 0


def test_bigger_budget_bigger_tiles():
    small = choose_blocking(8, 64, 64, 56, 56, 3, 3, vmem_words=16 * 1024)
    big = choose_blocking(8, 64, 64, 56, 56, 3, 3, vmem_words=1024 * 1024)
    upd = lambda b: b.block_n * b.block_ci * b.block_co * b.block_wo * b.block_ho
    assert upd(big) > upd(small)


def test_footprint_formula():
    # unit tile of a 3x3 stride-1 conv: input 3x3, filter ci*co*9, output 1
    fp = footprint_words(1, 2, 4, 1, 1, 3, 3, 1, 1)
    assert fp == 1 * 2 * 9 + 2 * 4 * 9 + 1 * 4 * 1


def test_chosen_blocking_runs_in_kernel():
    n, ci, co, wo, ho, wf, hf = 4, 8, 8, 6, 6, 3, 3
    b = choose_blocking(n, ci, co, wo, ho, wf, hf, vmem_words=8 * 1024,
                        spatial=False)
    x = jax.random.normal(jax.random.PRNGKey(0),
                          (n, ci, wo + wf - 1, ho + hf - 1), jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), (ci, co, wf, hf), jnp.float32)
    got = conv7nl_pallas(x, w, 1, 1, block_n=b.block_n,
                         block_ci=b.block_ci, block_co=b.block_co)
    want = conv7nl_ref(x, w, 1, 1)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=30, deadline=None)
@given(
    n=st.integers(1, 8),
    ci=st.sampled_from([1, 3, 8, 16]),
    co=st.sampled_from([1, 4, 12, 32]),
    wo=st.integers(1, 16),
    ho=st.integers(1, 16),
    budget=st.sampled_from([4096, 65536, 1 << 20]),
)
def test_blocking_always_feasible_and_divides(n, ci, co, wo, ho, budget):
    b = choose_blocking(n, ci, co, wo, ho, 3, 3, vmem_words=budget)
    assert b is not None
    assert b.footprint_words <= budget
    for dim, blk in [(n, b.block_n), (ci, b.block_ci), (co, b.block_co),
                     (wo, b.block_wo), (ho, b.block_ho)]:
        assert dim % blk == 0
