"""L1 correctness: the Pallas conv kernel vs the pure-jnp oracle.

This is the CORE numerical signal of the build path: if these pass, the HLO
artifacts the Rust runtime executes compute the paper's eq. (1) exactly.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.conv2d import conv7nl_pallas
from compile.kernels.ref import conv7nl_lax, conv7nl_ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32).astype(dtype)


def divisors(n):
    return [d for d in range(1, n + 1) if n % d == 0]


# ---------------------------------------------------------------- oracles

def test_ref_matches_lax_conv():
    x = rand(0, (2, 4, 12, 10))
    w = rand(1, (4, 6, 3, 3))
    a = conv7nl_ref(x, w, 1, 1)
    b = conv7nl_lax(x, w, 1, 1)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_ref_matches_lax_strided():
    x = rand(2, (1, 3, 23, 17))
    w = rand(3, (3, 5, 5, 3))
    a = conv7nl_ref(x, w, 2, 2)
    b = conv7nl_lax(x, w, 2, 2)
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


# ------------------------------------------------------------- fixed cases

@pytest.mark.parametrize("blocks", [(None, None, None), (2, 4, 8), (1, 2, 4), (4, 8, 16)])
def test_pallas_blockings_match_ref(blocks):
    bn, bci, bco = blocks
    x = rand(4, (4, 8, 14, 14))
    w = rand(5, (8, 16, 3, 3))
    got = conv7nl_pallas(x, w, 2, 2, block_n=bn, block_ci=bci, block_co=bco)
    want = conv7nl_ref(x, w, 2, 2)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_pallas_1x1_filter():
    x = rand(6, (2, 8, 6, 6))
    w = rand(7, (8, 4, 1, 1))
    got = conv7nl_pallas(x, w, 1, 1, block_ci=4)
    want = conv7nl_ref(x, w, 1, 1)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_pallas_asymmetric_strides():
    x = rand(8, (2, 4, 17, 11))
    w = rand(9, (4, 4, 3, 2))
    got = conv7nl_pallas(x, w, 2, 1)
    want = conv7nl_ref(x, w, 2, 1)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_pallas_bf16_inputs_f32_accum():
    # mixed precision: bf16 operands, f32 accumulator (the paper's GEMMINI
    # low-precision-in / high-precision-accumulate regime)
    x = rand(10, (2, 8, 10, 10), jnp.bfloat16)
    w = rand(11, (8, 8, 3, 3), jnp.bfloat16)
    got = conv7nl_pallas(x, w, 1, 1, block_ci=4, acc_dtype=jnp.float32)
    assert got.dtype == jnp.float32
    want = conv7nl_ref(x, w, 1, 1)
    # bf16 has ~3 decimal digits; tolerance accordingly
    np.testing.assert_allclose(got, want, rtol=5e-2, atol=5e-2)


def test_pallas_rejects_nondividing_blocks():
    x = rand(12, (4, 8, 8, 8))
    w = rand(13, (8, 8, 3, 3))
    with pytest.raises(AssertionError):
        conv7nl_pallas(x, w, 1, 1, block_n=3)


# ---------------------------------------------------------- hypothesis sweep

@settings(max_examples=40, deadline=None)
@given(
    n=st.integers(1, 4),
    ci=st.sampled_from([1, 2, 4, 8]),
    co=st.sampled_from([1, 2, 4, 8]),
    wo=st.integers(1, 6),
    ho=st.integers(1, 6),
    wf=st.integers(1, 4),
    hf=st.integers(1, 4),
    sw=st.integers(1, 2),
    sh=st.integers(1, 2),
    data=st.data(),
)
def test_pallas_matches_ref_random_shapes(n, ci, co, wo, ho, wf, hf, sw, sh, data):
    # paper model assumptions: σ ≤ f (all image elements used)
    if sw > wf or sh > hf:
        return
    in_w = sw * (wo - 1) + wf
    in_h = sh * (ho - 1) + hf
    x = rand(data.draw(st.integers(0, 2**16)), (n, ci, in_w, in_h))
    w = rand(data.draw(st.integers(0, 2**16)), (ci, co, wf, hf))
    bn = data.draw(st.sampled_from(divisors(n)))
    bci = data.draw(st.sampled_from(divisors(ci)))
    bco = data.draw(st.sampled_from(divisors(co)))
    got = conv7nl_pallas(x, w, sw, sh, out_w=wo, out_h=ho,
                         block_n=bn, block_ci=bci, block_co=bco)
    want = conv7nl_ref(x, w, sw, sh, out_w=wo, out_h=ho)
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)
