"""AOT path: lowering produces loadable HLO text and a consistent manifest."""

import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from compile.aot import build_all, lower_layer, lower_network, to_hlo_text
from compile.model import single_layer_specs, tiny_resnet_specs

jax.config.update("jax_platform_name", "cpu")


def test_to_hlo_text_produces_parsable_module():
    def fn(x):
        return (x @ x + 1.0,)

    spec = jax.ShapeDtypeStruct((4, 4), jnp.float32)
    text = to_hlo_text(jax.jit(fn).lower(spec))
    assert "HloModule" in text
    assert "ROOT" in text
    # the paper of record for this repo: output must be a tuple (the Rust
    # loader calls to_tuple1)
    assert "tuple" in text.lower()


def test_lower_layer_both_kinds():
    spec = single_layer_specs(2)[0]
    for kind in ("blocked", "im2col"):
        text = lower_layer(spec, kind)
        assert "HloModule" in text
        assert len(text) > 1000


def test_lower_network():
    specs = tiny_resnet_specs(2)
    text = lower_network(specs, 2)
    assert "HloModule" in text


def test_build_all_manifest_consistent():
    with tempfile.TemporaryDirectory() as d:
        manifest = build_all(d, batch=2)
        # files exist and are nonempty
        for art in manifest["artifacts"]:
            path = os.path.join(d, art["path"])
            assert os.path.getsize(path) > 0
            assert len(art["output"]) == 4
            assert art["updates"] > 0
        # manifest on disk parses and matches
        with open(os.path.join(d, "manifest.json")) as f:
            ondisk = json.load(f)
        assert ondisk == manifest
        # every single-layer spec appears in both kinds
        names = {(a["name"], a["kind"]) for a in manifest["artifacts"]}
        for spec in single_layer_specs(2):
            assert (spec.name, "blocked") in names
            assert (spec.name, "im2col") in names
        assert ("tiny_resnet", "network") in names


def test_lowered_layer_is_numerically_correct_via_jit():
    # execute the same jitted function that gets lowered, as a final check
    # that what we serialize is what we validated
    from compile.kernels.ref import conv7nl_ref
    from compile.model import conv_layer

    spec = single_layer_specs(2)[0]
    x = jax.random.normal(jax.random.PRNGKey(0), spec.input_shape, jnp.float32)
    w = jax.random.normal(jax.random.PRNGKey(1), spec.filter_shape, jnp.float32)
    got = jax.jit(lambda a, b: conv_layer(a, b, spec))(x, w)
    want = conv7nl_ref(x, w, spec.stride_w, spec.stride_h,
                       out_w=spec.out_w, out_h=spec.out_h)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
