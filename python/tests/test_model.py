"""L2 correctness: blocked conv layers (spatial tiling with halos) and the
tiny CNN forward pass that aot.py lowers."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels.ref import conv7nl_ref
from compile.model import (ConvSpec, conv_layer, conv_layer_im2col,
                           network_forward, single_layer_specs,
                           tiny_resnet_specs)

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


def spec_small(**kw):
    base = dict(name="t", n=2, c_in=4, c_out=6, out_w=8, out_h=8,
                filt_w=3, filt_h=3)
    base.update(kw)
    return ConvSpec(**base)


def test_spec_shapes_follow_paper_convention():
    s = spec_small(stride_w=2, stride_h=2)
    assert s.in_w == 2 * 8 + 3
    assert s.input_shape == (2, 4, 19, 19)
    assert s.filter_shape == (4, 6, 3, 3)
    assert s.output_shape == (2, 6, 8, 8)
    assert s.updates == 2 * 4 * 6 * 8 * 8 * 3 * 3


def test_conv_layer_no_spatial_blocking_matches_ref():
    s = spec_small()
    x = rand(0, s.input_shape)
    w = rand(1, s.filter_shape)
    got = conv_layer(x, w, s)
    want = conv7nl_ref(x, w, 1, 1, out_w=s.out_w, out_h=s.out_h)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bwo,bho", [(4, 4), (8, 4), (2, 8), (4, 2)])
def test_conv_layer_spatial_blocking_matches_ref(bwo, bho):
    s = spec_small(block_wo=bwo, block_ho=bho, block_ci=2, block_co=3)
    x = rand(2, s.input_shape)
    w = rand(3, s.filter_shape)
    got = conv_layer(x, w, s)
    want = conv7nl_ref(x, w, 1, 1, out_w=s.out_w, out_h=s.out_h)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_conv_layer_strided_spatial_blocking():
    s = spec_small(stride_w=2, stride_h=2, block_wo=4, block_ho=4)
    x = rand(4, s.input_shape)
    w = rand(5, s.filter_shape)
    got = conv_layer(x, w, s)
    want = conv7nl_ref(x, w, 2, 2, out_w=s.out_w, out_h=s.out_h)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_conv_layer_im2col_agrees():
    s = spec_small(stride_w=2, stride_h=1)
    x = rand(6, s.input_shape)
    w = rand(7, s.filter_shape)
    a = conv_layer(x, w, s)
    b = conv_layer_im2col(x, w, s)
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)


def test_nondividing_spatial_block_rejected():
    s = spec_small(block_wo=3)  # 8 % 3 != 0
    x = rand(8, s.input_shape)
    w = rand(9, s.filter_shape)
    with pytest.raises(AssertionError):
        conv_layer(x, w, s)


def test_network_forward_matches_layerwise_reference():
    specs = tiny_resnet_specs(batch=2)
    x = rand(10, specs[0].input_shape)
    weights = [rand(20 + i, s.filter_shape) for i, s in enumerate(specs)]
    got = network_forward(x, weights, specs)

    act = x
    for w, s in zip(weights, specs):
        want_shape = s.input_shape
        pad_w = want_shape[2] - act.shape[2]
        pad_h = want_shape[3] - act.shape[3]
        if pad_w or pad_h:
            act = jnp.pad(act, ((0, 0), (0, 0), (0, pad_w), (0, pad_h)))
        act = conv7nl_ref(act, w, s.stride_w, s.stride_h,
                          out_w=s.out_w, out_h=s.out_h)
        act = jnp.maximum(act, 0.0)
    np.testing.assert_allclose(got, act, rtol=1e-4, atol=1e-4)
    assert got.shape == specs[-1].output_shape


def test_tiny_resnet_specs_chain_spatially():
    specs = tiny_resnet_specs(batch=4)
    for prev, nxt in zip(specs, specs[1:]):
        assert prev.c_out == nxt.c_in, "channel chaining"
        # activation can only need upward padding, never cropping
        assert prev.out_w <= nxt.in_w
        assert prev.out_h <= nxt.in_h


def test_single_layer_specs_have_valid_blocks():
    for s in single_layer_specs(4):
        if s.block_ci:
            assert s.c_in % s.block_ci == 0, s.name
        if s.block_co:
            assert s.c_out % s.block_co == 0, s.name
        if s.block_wo:
            assert s.out_w % s.block_wo == 0, s.name
