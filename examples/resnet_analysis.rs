//! Full ResNet-50 analysis sweep: regenerates the Figure 2 and Figure 3
//! data series for every catalog layer and writes CSVs under `target/figures/`.
//!
//! ```bash
//! cargo run --release --example resnet_analysis
//! ```

use convbound::bench::write_csv;
use convbound::conv::{resnet50_layers, Precision};
use convbound::report::{
    default_mem_sweep, default_proc_sweep, fig2_series, fig3_series, ratio_table,
};

fn main() {
    let p = Precision::paper_mixed();
    let layers = resnet50_layers(1000);

    println!("=== Figure 2: sequential communication / lower bound vs M ===\n");
    for l in &layers[..2] {
        // the paper plots conv1 and conv2_x; conv3..5 "resemble conv2_x"
        println!("--- {} ---", l.name);
        let rows = fig2_series(&l.shape, p, &default_mem_sweep());
        print!("{}", ratio_table("M (words)", &rows).render());
        println!();
        let csv: Vec<Vec<f64>> = rows
            .iter()
            .map(|(m, r)| {
                let mut row = vec![*m];
                row.extend(r.iter().map(|(_, v)| *v));
                row
            })
            .collect();
        let path = format!("target/figures/fig2_{}.csv", l.name);
        write_csv(&path, &["M", "naive", "im2col", "blocking", "winograd", "fft"], &csv)
            .expect("write csv");
        println!("wrote {path}\n");
    }

    println!("=== Figure 3: parallel communication / lower bound vs P ===\n");
    for l in &layers[..2] {
        println!("--- {} ---", l.name);
        let rows = fig3_series(&l.shape, p, &default_proc_sweep(), 1e6);
        print!("{}", ratio_table("P", &rows).render());
        println!();
        let csv: Vec<Vec<f64>> = rows
            .iter()
            .map(|(pp, r)| {
                let mut row = vec![*pp as f64];
                row.extend(r.iter().map(|(_, v)| *v));
                row
            })
            .collect();
        let path = format!("target/figures/fig3_{}.csv", l.name);
        write_csv(&path, &["P", "naive", "im2col", "blocking", "winograd", "fft"], &csv)
            .expect("write csv");
        println!("wrote {path}\n");
    }

    println!("=== remaining layers (conv3_x..conv5_x resemble conv2_x) ===\n");
    for l in &layers[2..] {
        let rows = fig2_series(&l.shape, p, &[65536.0, 1048576.0]);
        println!("--- {} (spot check) ---", l.name);
        print!("{}", ratio_table("M (words)", &rows).render());
        println!();
    }
}
