//! Training-step driver: one fused sweep per SGD step through a whole
//! network, with the loss boundary as the only materialization.
//!
//! ```bash
//! cargo run --release --example training_step
//! ```
//!
//! This exercises the paper's point that a training step is *three* 7NL
//! CNN computations per layer (forward, dFilter, dInput — see
//! conv/training.rs), and the engine's answer to it: plan the whole chain
//! once with `FusePlan::for_pass(NetPass::Step, ..)` and run every step as
//! a single fused sweep (`conv_network_step_counted`). Inside a fused
//! group the forward activations are recomputed in-tile and the gradients
//! stay resident, so the only tensors that touch main memory between the
//! stages are the ones SGD itself needs — the loss gradient in, the
//! filter gradients and the image gradient out. The driver checks all
//! three claims every run:
//!
//! * the fused gradients are bitwise identical to the layer-by-layer
//!   SGD oracle (`naive_network_step`) — tiny_resnet fuses into a single
//!   group, so `FusePlan::step_bitwise` holds;
//! * the measured per-stage traffic matches the plan's analytic model
//!   (`expected_network_traffic`) exactly, with zero words crossing the
//!   fused boundaries;
//! * the loss actually falls.

use convbound::bounds::sequential_bound;
use convbound::conv::Tensor4;
use convbound::kernels::{
    conv_network_fused_counted, conv_network_step_counted, naive_network,
    naive_network_step, FusePlan, NetPass, NetTrafficCounters, TilePlanCache,
    Traffic, DEFAULT_TILE_MEM_WORDS,
};
use convbound::runtime::NetworkSpec;

fn main() {
    // CONVBOUND_TRACE=<path> streams the run's plan/traffic events
    convbound::obs::init_from_env();
    let net = NetworkSpec::tiny_resnet(2);
    let cache = TilePlanCache::new();

    // the communication story of the step, stage by stage
    println!("== per-stage Theorem 2.1 bounds at M = 64K words ==");
    for (k, st) in net.stages.iter().enumerate() {
        println!(
            "  stage {k}: G = {:>9}  X >= {:.3e} words",
            st.shape.updates(),
            sequential_bound(&st.shape, st.precision, DEFAULT_TILE_MEM_WORDS)
        );
    }

    // one plan per pass, solved once and reused for every SGD step
    let fwd = FusePlan::new(&net.stages, DEFAULT_TILE_MEM_WORDS, &cache);
    let step = FusePlan::for_pass(
        NetPass::Step,
        &net.stages,
        DEFAULT_TILE_MEM_WORDS,
        &cache,
    );
    println!(
        "\n== fused training step: {} stages, {} fused boundaries ==",
        net.stages.len(),
        step.fused_boundaries()
    );
    assert!(
        step.step_bitwise(),
        "tiny_resnet must fuse into one group at the default budget"
    );

    // teacher-student: fit the filters to reproduce a fixed teacher
    let image = Tensor4::randn(net.input_dims(), 11);
    let teacher: Vec<Tensor4> = net
        .stages
        .iter()
        .enumerate()
        .map(|(i, st)| Tensor4::randn(st.shape.filter_dims(), 20 + i as u64))
        .collect();
    let trefs: Vec<&Tensor4> = teacher.iter().collect();
    let target = naive_network(&image, &trefs, &net.stages);
    let mut filters: Vec<Tensor4> = net
        .stages
        .iter()
        .enumerate()
        .map(|(i, st)| Tensor4::randn(st.shape.filter_dims(), 30 + i as u64))
        .collect();

    println!("\n== SGD on ||net(x) - target||² as one fused sweep per step ==");
    let lr = 2e-3_f32;
    let counters = NetTrafficCounters::new(net.stages.len());
    let mut first_loss = None;
    let mut last_loss = 0.0;
    for sgd_step in 0..30 {
        let frefs: Vec<&Tensor4> = filters.iter().collect();
        // forward sweep for the loss boundary
        let fwd_counters = NetTrafficCounters::new(net.stages.len());
        let out = conv_network_fused_counted(&image, &frefs, &fwd, &fwd_counters);
        // residual g = out - target; loss = ||g||²/2
        let mut gout = out.clone();
        for (gv, tv) in gout.data.iter_mut().zip(&target.data) {
            *gv -= tv;
        }
        let loss: f32 = gout.data.iter().map(|v| v * v).sum::<f32>() / 2.0;
        // the whole backward half of the step: one fused sweep
        let (dfilters, _dimage) =
            conv_network_step_counted(&image, &frefs, &gout, &step, &counters);
        if sgd_step == 0 {
            first_loss = Some(loss);
            // validate the fused gradients against the layer-by-layer SGD
            // oracle once — bitwise, since the plan is a single fused group
            let (dw_ref, din_ref) =
                naive_network_step(&image, &frefs, &gout, &net.stages);
            assert_eq!(_dimage.max_abs_diff(&din_ref), 0.0, "dImage");
            for (k, (dw, want)) in
                dfilters.iter().zip(dw_ref.iter()).enumerate()
            {
                assert_eq!(dw.max_abs_diff(want), 0.0, "dFilter stage {k}");
            }
            println!("  gradient check vs layer-by-layer oracle: bitwise OK");
        }
        for (w, dw) in filters.iter_mut().zip(dfilters.iter()) {
            for (wv, gv) in w.data.iter_mut().zip(&dw.data) {
                *wv -= lr * gv;
            }
        }
        last_loss = loss;
        if sgd_step % 10 == 0 {
            println!("  step {sgd_step:>3}: loss {loss:.4}");
        }
    }
    let first = first_loss.unwrap();
    println!("  final loss {last_loss:.4} (from {first:.4})");
    assert!(last_loss < first * 0.5, "SGD must reduce the loss");

    // the traffic story: measured == analytic model, fused boundaries dry
    let measured = counters.snapshot();
    let per_step: Vec<Traffic> = {
        let want = step.expected_network_traffic();
        measured
            .iter()
            .zip(want.iter())
            .map(|(m, w)| {
                assert_eq!(m.total() % 30, 0, "30 identical sweeps");
                let once = Traffic {
                    input_words: m.input_words / 30,
                    filter_words: m.filter_words / 30,
                    output_words: m.output_words / 30,
                };
                assert_eq!(
                    once.total(),
                    w.total(),
                    "measured step traffic must match the analytic model"
                );
                once
            })
            .collect()
    };
    assert_eq!(step.boundary_words(&per_step), 0, "fused boundaries");
    println!(
        "\nper-step traffic {} words, fused boundaries 0 words — \
         training driver complete: loss reduced {:.1}x",
        Traffic::sum(&per_step).total(),
        first / last_loss
    );
}
