//! Training-step driver: forward + backward convolutions through the
//! runtime, with an SGD update loop showing the loss actually falls.
//!
//! ```bash
//! cargo run --release --example training_step          # builtin, no setup
//! make artifacts && cargo run --release --example training_step  # AOT
//! ```
//!
//! This exercises the paper's point that a training step is *three* 7NL
//! CNN computations (forward, dFilter, dInput — see conv/training.rs).
//! With an `artifacts/` directory the passes run as AOT-lowered HLO; with
//! none, `Manifest::builtin`'s `"dfilter"` artifact routes the gradient
//! through the pass-generic LP-tiled engine (`kernels/`), which is bitwise
//! identical to the naive oracle — so the same driver runs end to end with
//! zero setup.

use convbound::bounds::sequential_bound;
use convbound::conv::{
    backward_shapes, conv7nl_naive, dfilter_naive, ConvShape, Precision, Tensor4,
};
use convbound::runtime::Runtime;

fn artifact_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn main() {
    let mut rt = if artifact_dir().join("manifest.json").exists() {
        Runtime::new(artifact_dir()).expect("runtime")
    } else {
        println!("no artifacts/ — training on the built-in native backend");
        Runtime::builtin()
    };
    let fwd = rt.manifest().find("unit3x3/blocked").expect("fwd artifact").clone();
    let has_grad = rt.manifest().find("unit3x3/dfilter").is_some();
    if !has_grad {
        eprintln!("gradient artifacts missing — re-run `make artifacts`");
        std::process::exit(1);
    }

    let xd = fwd.inputs[0].clone();
    let wd = fwd.inputs[1].clone();
    let od = fwd.output.clone();
    let shape = ConvShape::new(
        xd[0] as u64, wd[0] as u64, wd[1] as u64, od[2] as u64, od[3] as u64,
        wd[2] as u64, wd[3] as u64,
        ((xd[2] - wd[2]) / od[2]) as u64,
        ((xd[3] - wd[3]) / od[3]) as u64,
    );

    // the communication story of the step: three bounds
    let t = backward_shapes(shape);
    let p = Precision::uniform();
    println!("== per-pass Theorem 2.1 bounds at M = 64K words ==");
    for (name, s) in [("forward", t.forward), ("dFilter", t.dfilter), ("dInput", t.dinput)] {
        println!("  {name:<8} G = {:>10}  X >= {:.3e} words", s.updates(),
                 sequential_bound(&s, p, 65536.0));
    }

    // teacher-student: fit w to reproduce a fixed teacher's outputs
    let x = Tensor4::randn([xd[0], xd[1], xd[2], xd[3]], 11);
    let w_teacher = Tensor4::randn([wd[0], wd[1], wd[2], wd[3]], 12);
    let target = conv7nl_naive(&x, &w_teacher, &shape);
    let mut w = Tensor4::randn([wd[0], wd[1], wd[2], wd[3]], 13);

    rt.load("unit3x3/blocked").expect("compile fwd");
    rt.load("unit3x3/dfilter").expect("compile dfilter");

    println!("\n== SGD on ||conv(x, w) - target||² through the artifacts ==");
    let lr = 1e-3_f32;
    let mut first_loss = None;
    let mut last_loss = 0.0;
    for step in 0..30 {
        let out = rt.run("unit3x3/blocked", &[&x, &w]).expect("fwd");
        // residual g = out - target; loss = ||g||²/2
        let mut g = out.clone();
        for (gv, tv) in g.data.iter_mut().zip(&target.data) {
            *gv -= tv;
        }
        let loss: f32 = g.data.iter().map(|v| v * v).sum::<f32>() / 2.0;
        if step == 0 {
            first_loss = Some(loss);
            // validate the artifact gradient against the naive oracle once
            let dw_art = rt.run("unit3x3/dfilter", &[&x, &g]).expect("dfilter");
            let dw_ref = dfilter_naive(&x, &g, &shape);
            let rel = dw_art.rel_l2(&dw_ref);
            assert!(rel < 1e-5, "dfilter artifact vs oracle rel_l2 {rel}");
            println!("  gradient check vs naive oracle: rel_l2 = {rel:.2e} OK");
        }
        let dw = rt.run("unit3x3/dfilter", &[&x, &g]).expect("dfilter");
        for (wv, gv) in w.data.iter_mut().zip(&dw.data) {
            *wv -= lr * gv;
        }
        last_loss = loss;
        if step % 10 == 0 {
            println!("  step {step:>3}: loss {loss:.4}");
        }
    }
    let first = first_loss.unwrap();
    println!("  final loss {last_loss:.4} (from {first:.4})");
    assert!(last_loss < first * 0.5, "SGD must reduce the loss");
    println!("\ntraining step driver complete: loss reduced {:.1}x", first / last_loss);
}
