//! Quickstart: bounds + communication-optimal blocking for one layer.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Walks the library's core loop for ResNet-50 conv2_x at batch 1000:
//! 1. evaluate the Theorem 2.1 lower bound at a 256 KiB cache,
//! 2. solve the §3.2 blocking LP and inspect the tile,
//! 3. compare the major convolution algorithms' communication volumes,
//! 4. compute a GEMMINI tile and simulate it against the vendor tiling,
//! 5. *execute* the blocking on a runnable-size variant through the
//!    `kernels/` tiled engine, checking numerics and measured traffic.

use convbound::bounds::sequential_bound_terms;
use convbound::commvol::sequential_volumes;
use convbound::conv::{
    conv7nl_naive, paper_operands, resnet50_layers, scaled, Precision,
};
use convbound::gemmini::{simulate_layer, GemminiConfig};
use convbound::kernels::{conv_tiled_counted, TilePlan, TrafficCounters};
use convbound::tiling::{
    optimize_gemmini_tiling, sequential_blocking, vendor_tiling, OptOptions,
};

fn main() {
    let layer = resnet50_layers(1000)[1]; // conv2_x
    let shape = layer.shape;
    let p = Precision::paper_mixed();
    let m = 65536.0; // 256 KiB cache in words

    println!("== layer {} : {shape}\n", layer.name);

    // 1. the lower bound
    let b = sequential_bound_terms(&shape, p, m);
    println!("Theorem 2.1 at M = {m} words:");
    println!("  compulsory   {:>12.3e}", b.compulsory);
    println!("  HBL          {:>12.3e}", b.hbl);
    println!("  small-filter {:>12.3e}", b.small_filter);
    println!("  X >= {:.3e} words ({} term dominates)\n", b.max(), b.dominant());

    // 2. the LP blocking
    let blk = sequential_blocking(&shape, p, m);
    println!("LP blocking (paper §3.2, with the small-filter split):");
    println!("  bN={} bcI={} bcO={} bwO={} bhO={} q-blocks=({}, {}) r-blocks=({}, {})",
             blk.b_n, blk.b_ci, blk.b_co, blk.b_wo, blk.b_ho,
             blk.b_wf_q, blk.b_hf_q, blk.b_wf_r, blk.b_hf_r);
    println!("  updates/tile = {:.3e}, tile footprint = {:.0} of {m} words\n",
             blk.updates_per_tile(), blk.footprint_words(p));

    // 3. algorithm comparison (one Figure-2 column)
    let v = sequential_volumes(&shape, p, m);
    println!("communication volumes at M = {m} (ratio to bound):");
    for (name, ratio) in v.ratios() {
        println!("  {name:<9} {ratio:>8.2}x");
    }
    println!();

    // 4. GEMMINI: ours vs vendor
    let cfg = GemminiConfig::default();
    let ours = optimize_gemmini_tiling(&shape, &cfg, OptOptions::default());
    let vend = vendor_tiling(&shape, &cfg);
    let ro = simulate_layer(&shape, &cfg, &ours);
    let rv = simulate_layer(&shape, &cfg, &vend);
    println!("GEMMINI (simulated):");
    println!("  ours   {:?} -> {:.3e} cycles, {:.3e} comm rows", ours, ro.cycles as f64, ro.comm_rows as f64);
    println!("  vendor {:?} -> {:.3e} cycles, {:.3e} comm rows", vend, rv.cycles as f64, rv.comm_rows as f64);
    println!("  communication: {:.0}% of vendor; cycles: {:.2}x vendor",
             ro.comm_rows as f64 / rv.comm_rows as f64 * 100.0,
             ro.cycles as f64 / rv.cycles as f64);
    println!();

    // 5. execute the tiling for real (runnable-size variant of the layer)
    let small = scaled(shape.with_batch(4), 4);
    let plan = TilePlan::new(&small, Precision::uniform(), m);
    let (x, w) = paper_operands(&small, 1);
    let counters = TrafficCounters::new();
    let out = conv_tiled_counted(&x, &w, &plan, &counters);
    let rel = out.rel_l2(&conv7nl_naive(&x, &w, &small));
    let t = counters.snapshot();
    println!("tiled execution of {small} ({} tiles):", plan.total_tiles());
    println!("  rel_l2 vs naive oracle = {rel:.2e}");
    println!(
        "  measured traffic: input {} + filter {} + output {} = {} words",
        t.input_words, t.filter_words, t.output_words, t.total()
    );
    assert!(rel < 1e-4, "tiled engine disagrees with the oracle");
}
