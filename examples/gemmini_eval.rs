//! Figure 4 + §5 claims: the GEMMINI evaluation, ours vs vendor tiling,
//! on all five ResNet-50 convolution sizes at batch 1000.
//!
//! ```bash
//! cargo run --release --example gemmini_eval [-- --batch 1000]
//! ```

use convbound::gemmini::GemminiConfig;
use convbound::report::{fig4_rows, fig4_table};
use convbound::util::cli::Args;
use convbound::util::stats::geomean;

fn main() {
    let args = Args::parse(std::env::args().skip(1));
    let batch = args.opt_u64("batch", 1000).unwrap_or_else(|e| {
        // same rendering + exit code as the convbound CLI's error contract
        eprintln!("error: {e}");
        std::process::exit(1);
    });
    let cfg = GemminiConfig::default();

    println!("=== Figure 4: GEMMINI, batch {batch}, paper objective ===\n");
    let rows = fig4_rows(batch, &cfg, false);
    print!("{}", fig4_table(&rows).render());

    println!("\n=== with the §5 conv5 extra constraint (no tiling of ≤7px images) ===\n");
    let fixed = fig4_rows(batch, &cfg, true);
    print!("{}", fig4_table(&fixed).render());

    println!("\n=== §5 claims vs measured ===");
    let comm: Vec<f64> = rows.iter().map(|r| r.comm_ratio()).collect();
    println!(
        "paper: communication 45%–85% of vendor  | measured: {:.0}%–{:.0}% (geomean {:.0}%)",
        comm.iter().cloned().fold(f64::INFINITY, f64::min) * 100.0,
        comm.iter().cloned().fold(0.0, f64::max) * 100.0,
        geomean(&comm) * 100.0
    );
    for (r, rf) in rows.iter().zip(&fixed) {
        println!(
            "  {:<8} cycles {:.2}x vendor (with small-image constraint: {:.2}x)",
            r.name,
            r.cycle_ratio(),
            rf.cycle_ratio()
        );
    }
    println!(
        "paper: conv5 regression 124% -> 104% with one extra constraint | measured: {:.0}% -> {:.0}%",
        rows[4].cycle_ratio() * 100.0,
        fixed[4].cycle_ratio() * 100.0
    );
}
