//! End-to-end driver: proves all three layers compose on a real workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_network
//! ```
//!
//! Pipeline exercised:
//!   1. **Plan** — every layer of the tiny CNN gets its LP blocking and
//!      GEMMINI tile (the paper's contribution) from the coordinator.
//!   2. **Execute** — the AOT-compiled JAX+Pallas network artifact
//!      (`artifacts/network_tiny_resnet.hlo.txt`, blocked per the same
//!      tiling scheme) runs batched inference on the PJRT CPU client.
//!   3. **Serve** — single-image requests stream through the batching
//!      ConvServer for one of the layer artifacts (latency/throughput).
//!   4. **Validate** — outputs are checked against the in-Rust naive 7NL
//!      oracle; the accelerator-level comm/cycle story is reported from
//!      the GEMMINI simulator for the same shapes.
//!
//! Results from this driver are recorded in EXPERIMENTS.md §E2E.

use std::time::{Duration, Instant};

use convbound::conv::{conv7nl_naive, ConvShape, Precision, Tensor4};
use convbound::coordinator::{ConvServer, Planner};
use convbound::gemmini::{simulate_layer, GemminiConfig};
use convbound::runtime::Runtime;
use convbound::tiling::vendor_tiling;

fn artifact_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn main() {
    if !artifact_dir().join("manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }

    // the tiny CNN the artifacts encode (must mirror model.tiny_resnet_specs)
    let batch = 4u64;
    let layers = [
        ("conv1", ConvShape::new(batch, 3, 12, 15, 15, 5, 5, 2, 2)),
        ("conv2", ConvShape::new(batch, 12, 16, 12, 12, 3, 3, 1, 1)),
        ("conv3", ConvShape::new(batch, 16, 32, 5, 5, 3, 3, 2, 2)),
    ];

    // ---- 1. plan ----------------------------------------------------
    println!("== planning ({} layers) ==", layers.len());
    let planner = Planner { precision: Precision::uniform(), ..Default::default() };
    let named: Vec<(String, ConvShape)> =
        layers.iter().map(|(n, s)| (n.to_string(), *s)).collect();
    let plans = planner.plan_network(&named);
    for plan in &plans {
        println!(
            "  {:<6} blocking bN={} bcI={} bcO={} bwO={} bhO={} | gemmini tile {:?} | bound {:.2e} words",
            plan.name, plan.blocking.b_n, plan.blocking.b_ci, plan.blocking.b_co,
            plan.blocking.b_wo, plan.blocking.b_ho, plan.gemmini, plan.bound.max()
        );
    }

    // ---- 2. execute the network artifact ----------------------------
    println!("\n== batched network inference over PJRT ==");
    let mut rt = Runtime::new(artifact_dir()).expect("runtime");
    println!("platform: {}", rt.platform());
    let spec = rt.manifest().find("tiny_resnet/network").expect("network artifact").clone();
    let inputs: Vec<Tensor4> = spec
        .inputs
        .iter()
        .enumerate()
        .map(|(i, d)| Tensor4::randn([d[0], d[1], d[2], d[3]], 40 + i as u64))
        .collect();
    let refs: Vec<&Tensor4> = inputs.iter().collect();
    rt.load("tiny_resnet/network").expect("compile network");
    // warmup + timed steps
    let _ = rt.run("tiny_resnet/network", &refs).expect("warmup");
    let steps = 20;
    let t0 = Instant::now();
    let mut out = None;
    for _ in 0..steps {
        out = Some(rt.run("tiny_resnet/network", &refs).expect("run"));
    }
    let dt = t0.elapsed().as_secs_f64();
    let out = out.unwrap();
    let macs = spec.updates as f64;
    println!(
        "ran {steps} batched steps in {dt:.3}s -> {:.1} inf/s, {:.2} MMAC/s",
        steps as f64 * batch as f64 / dt,
        steps as f64 * macs / dt / 1e6
    );

    // ---- 4a. validate against the naive oracle ----------------------
    let mut act = inputs[0].clone();
    for (li, (_, shape)) in layers.iter().enumerate() {
        let want_w = shape.in_w() as usize;
        let want_h = shape.in_h() as usize;
        if act.dims[2] < want_w || act.dims[3] < want_h {
            let mut padded = Tensor4::zeros([act.dims[0], act.dims[1], want_w, want_h]);
            for a in 0..act.dims[0] {
                for b in 0..act.dims[1] {
                    for c in 0..act.dims[2] {
                        for d in 0..act.dims[3] {
                            *padded.at_mut(a, b, c, d) = act.at(a, b, c, d);
                        }
                    }
                }
            }
            act = padded;
        }
        act = conv7nl_naive(&act, &inputs[1 + li], shape);
        for v in act.data.iter_mut() {
            *v = v.max(0.0);
        }
    }
    let rel = out.rel_l2(&act);
    println!("numerics vs naive 7NL oracle: rel_l2 = {rel:.2e} {}", if rel < 1e-4 { "OK" } else { "FAIL" });
    assert!(rel < 1e-4, "network output diverged from the oracle");

    // ---- 3. serve single-image requests through the batcher ---------
    println!("\n== batched serving (unit3x3/blocked) ==");
    let layer_spec = rt.manifest().find("unit3x3/blocked").expect("layer artifact").clone();
    let wd = &layer_spec.inputs[1];
    let weights = Tensor4::randn([wd[0], wd[1], wd[2], wd[3]], 7);
    let server = ConvServer::start(
        artifact_dir(), "unit3x3/blocked", weights.clone(), Duration::from_millis(2),
    )
    .expect("server");
    let xd = layer_spec.inputs[0].clone();
    let requests = 64;
    let t0 = Instant::now();
    let pending: Vec<_> = (0..requests)
        .map(|i| {
            let img = Tensor4::randn([1, xd[1], xd[2], xd[3]], 500 + i as u64);
            server.submit(img).expect("submit")
        })
        .collect();
    let mut latencies = Vec::new();
    for rx in pending {
        latencies.push(rx.recv().expect("response").latency.as_secs_f64());
    }
    let wall = t0.elapsed().as_secs_f64();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let stats = server.shutdown().expect("shutdown");
    println!(
        "{requests} requests in {wall:.3}s -> {:.1} req/s; latency p50 {:.2} ms p95 {:.2} ms",
        requests as f64 / wall,
        latencies[latencies.len() / 2] * 1e3,
        latencies[latencies.len() * 95 / 100] * 1e3
    );
    println!(
        "batches {} (size {}), padded slots {} ({:.0}% waste)",
        stats.batches,
        server_batch(&layer_spec),
        stats.padded_slots,
        stats.padded_slots as f64 / (stats.batches as f64 * server_batch(&layer_spec) as f64) * 100.0
    );

    // ---- 4b. accelerator-level story for the same shapes ------------
    println!("\n== GEMMINI comm/cycles for the tiny network's shapes ==");
    let cfg = GemminiConfig::default();
    for (plan, (name, shape)) in plans.iter().zip(&layers) {
        let ours = simulate_layer(shape, &cfg, &plan.gemmini);
        let vend = simulate_layer(shape, &cfg, &vendor_tiling(shape, &cfg));
        println!(
            "  {:<6} comm {:>6.1}% of vendor, cycles {:.2}x, MXU util {:.1}%",
            name,
            ours.comm_rows as f64 / vend.comm_rows as f64 * 100.0,
            ours.cycles as f64 / vend.cycles as f64,
            ours.mxu_utilization * 100.0
        );
    }
    println!("\nE2E driver complete.");
}

fn server_batch(spec: &convbound::runtime::ArtifactSpec) -> usize {
    spec.inputs[0][0]
}
