//! End-to-end driver: proves the whole stack composes on a real workload —
//! with **zero setup**: no artifacts directory, no Python, no PJRT. The
//! builtin `tiny_resnet` pipeline runs through `Runtime::builtin()` and the
//! fused network executor.
//!
//! ```bash
//! cargo run --release --example e2e_network
//! ```
//!
//! Pipeline exercised:
//!   1. **Plan** — every stage gets its LP blocking and GEMMINI tile from
//!      the coordinator, and the fusion planner decides per boundary
//!      whether the inter-layer activation stays resident or materializes.
//!   2. **Execute** — the `tiny_resnet/network` artifact runs batched
//!      fused inference on the native backend, reporting per-stage
//!      measured word traffic (fused boundaries must move zero words).
//!   3. **Validate** — the fused output is checked *bitwise* against the
//!      stage-by-stage naive 7NL oracle.
//!   4. **Serve** — single-image requests stream through the batching
//!      ConvServer over the whole network (latency/throughput).
//!   5. **Accelerate** — the GEMMINI comm/cycle story for the same shapes.

use std::sync::Arc;
use std::time::{Duration, Instant};

use convbound::conv::{ConvShape, Precision, Tensor4};
use convbound::coordinator::{ConvServer, Planner};
use convbound::gemmini::{simulate_layer, GemminiConfig};
use convbound::kernels::{
    naive_network, FusePlan, TilePlanCache, Traffic, DEFAULT_TILE_MEM_WORDS,
};
use convbound::runtime::Runtime;
use convbound::tiling::vendor_tiling;

fn main() {
    // CONVBOUND_TRACE=<path> streams the run's plan/traffic events
    convbound::obs::init_from_env();
    let mut rt = Runtime::builtin();
    let key = "tiny_resnet/network";
    let net = rt.manifest().network("tiny_resnet").expect("builtin network").clone();
    let batch = net.batch();

    // ---- 1. plan ----------------------------------------------------
    println!("== planning ({} stages) ==", net.stages.len());
    let planner = Planner { precision: Precision::uniform(), ..Default::default() };
    let named: Vec<(String, ConvShape)> = net
        .stages
        .iter()
        .enumerate()
        .map(|(i, st)| (format!("stage{i}"), st.shape))
        .collect();
    let plans = planner.plan_network(&named);
    for plan in &plans {
        println!(
            "  {:<7} blocking bN={} bcI={} bcO={} bwO={} bhO={} | gemmini tile {:?} | bound {:.2e} words",
            plan.name, plan.blocking.b_n, plan.blocking.b_ci, plan.blocking.b_co,
            plan.blocking.b_wo, plan.blocking.b_ho, plan.gemmini, plan.bound.max()
        );
    }
    let cache = TilePlanCache::new();
    let fuse = FusePlan::new(&net.stages, DEFAULT_TILE_MEM_WORDS, &cache);
    for g in &fuse.groups {
        if g.is_fused() {
            println!(
                "  fusion: stages {}..={} fused (tile N={} wO={} hO={})",
                g.start, g.end, g.b_n, g.b_wo, g.b_ho
            );
        } else {
            println!("  fusion: stage {} materialized", g.start);
        }
    }

    // ---- 2. execute the fused network pipeline ----------------------
    println!("\n== batched fused network inference (native backend) ==");
    println!("platform: {}", rt.platform());
    let spec = rt.manifest().find(key).expect("network artifact").clone();
    let inputs: Vec<Arc<Tensor4>> = spec
        .inputs
        .iter()
        .enumerate()
        .map(|(i, d)| Arc::new(Tensor4::randn([d[0], d[1], d[2], d[3]], 40 + i as u64)))
        .collect();
    rt.load(key).expect("load network");
    // warmup + timed steps over the zero-copy Arc path
    let _ = rt.run_arc(key, &inputs).expect("warmup");
    let steps = 50;
    let t0 = Instant::now();
    let mut out = None;
    for _ in 0..steps {
        out = Some(rt.run_arc(key, &inputs).expect("run"));
    }
    let dt = t0.elapsed().as_secs_f64();
    let out = out.unwrap();
    println!(
        "ran {steps} batched steps in {dt:.3}s -> {:.1} inf/s, {:.2} MMAC/s",
        steps as f64 * batch as f64 / dt,
        steps as f64 * spec.updates as f64 / dt / 1e6
    );
    let stage_traffic = rt.stage_traffic(key).expect("instrumented network");
    for (k, t) in stage_traffic.iter().enumerate() {
        println!(
            "  stage {k}: input {} + filter {} + output {} words",
            t.input_words, t.filter_words, t.output_words
        );
    }
    let fused_total = Traffic::sum(&stage_traffic).total();
    println!("  total measured traffic (all steps): {fused_total} words");

    // ---- 3. validate bitwise against the staged naive oracle --------
    let frefs: Vec<&Tensor4> = inputs[1..].iter().map(|a| a.as_ref()).collect();
    let want = naive_network(&inputs[0], &frefs, &net.stages);
    let diff = out.max_abs_diff(&want);
    println!(
        "numerics vs staged naive 7NL oracle: max_abs_diff = {diff} {}",
        if diff == 0.0 { "OK (bitwise)" } else { "FAIL" }
    );
    assert_eq!(diff, 0.0, "fused network diverged from the staged oracle");

    // ---- 4. serve whole-network requests through the batcher --------
    println!("\n== batched network serving ({key}) ==");
    let weights: Vec<Tensor4> = spec.inputs[1..]
        .iter()
        .enumerate()
        .map(|(i, d)| Tensor4::randn([d[0], d[1], d[2], d[3]], 7 + i as u64))
        .collect();
    let server =
        ConvServer::start_builtin_network(key, weights, Duration::from_millis(2))
            .expect("server");
    let xd = spec.inputs[0].clone();
    let requests = 64;
    let t0 = Instant::now();
    let pending: Vec<_> = (0..requests)
        .map(|i| {
            let img = Tensor4::randn([1, xd[1], xd[2], xd[3]], 500 + i as u64);
            server.submit(img).expect("submit")
        })
        .collect();
    let mut latencies = Vec::new();
    for rx in pending {
        latencies.push(rx.recv().expect("response").latency.as_secs_f64());
    }
    let wall = t0.elapsed().as_secs_f64();
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let batch_size = server.batch_size();
    let stats = server.shutdown().expect("shutdown");
    println!(
        "{requests} requests in {wall:.3}s -> {:.1} req/s; latency p50 {:.2} ms p95 {:.2} ms",
        requests as f64 / wall,
        latencies[latencies.len() / 2] * 1e3,
        latencies[latencies.len() * 95 / 100] * 1e3
    );
    println!(
        "batches {} (size {}), padded slots {} ({:.0}% waste)",
        stats.batches,
        batch_size,
        stats.padded_slots,
        stats.padded_slots as f64 / (stats.batches.max(1) as f64 * batch_size as f64) * 100.0
    );

    // ---- 5. accelerator-level story for the same shapes -------------
    println!("\n== GEMMINI comm/cycles for the network's shapes ==");
    let cfg = GemminiConfig::default();
    for (plan, st) in plans.iter().zip(&net.stages) {
        let ours = simulate_layer(&st.shape, &cfg, &plan.gemmini);
        let vend = simulate_layer(&st.shape, &cfg, &vendor_tiling(&st.shape, &cfg));
        println!(
            "  {:<7} comm {:>6.1}% of vendor, cycles {:.2}x, MXU util {:.1}%",
            plan.name,
            ours.comm_rows as f64 / vend.comm_rows as f64 * 100.0,
            ours.cycles as f64 / vend.cycles as f64,
            ours.mxu_utilization * 100.0
        );
    }
    println!("\nE2E driver complete.");
}
